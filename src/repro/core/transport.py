"""First-class gossip transports: HOW the doubly-stochastic mixing moves
parameters between nodes.

DPSVRG's convergence argument (Algorithm 1 + Theorem 1) only constrains the
mixing product ``Phi(l, g)`` — it is agnostic to the wire format that
executes it.  This module makes that axis a plugin, the same way
``core.algorithm`` made the method a plugin: a :class:`GossipBackend` owns

* ``prepare(schedule, meta, mesh=None) -> aux`` — static precompute (band
  offset unions, node-axis mesh setup) done once per run,
* ``phi_for(aux, slot, rounds) -> phi`` — the host-side per-step wire
  representation (a plain ``(m, m)`` array, a :class:`~repro.core.gossip.
  BandedPhi`, a :class:`~repro.core.gossip.PermutePhi`, ...).  Every
  representation is a pytree, so the runner stacks it through ``lax.scan``
  xs generically and algorithm steps dispatch on its type via
  ``gossip.mix_stacked`` without knowing which transport is active.
  Schedules are periodic, so the ``rounds``-product starting at ``slot``
  only depends on ``slot % period`` — ``phi_for`` memoizes its wire
  representations in the per-run ``aux`` on that key, turning the per-step
  host work (matrix products, band decompositions) into a dict lookup after
  the first period,
* ``mix(aux, phi, tree)`` — the actual collective (what ``mix_stacked``
  dispatches to), exposed for direct use by trainers and tests,
* ``bytes_per_step(aux, phi, param_count)`` — wire-cost accounting, so
  communication plots can report BYTES moved, not just gossip rounds;
  ``bytes_per_link(aux, phi, param_count)`` refines the same accounting to
  a ``{(src, dst): bytes}`` map over directed node links (summing exactly
  to ``bytes_per_step``), feeding per-edge communication plots.

Registered backends (:data:`GOSSIP_BACKENDS`):

``dense``
    One ``(m, m)`` contraction per step.  Under GSPMD the einsum all-gathers
    all m stacked copies to every node — O(m) wire cost — but arbitrary
    multi-consensus products stay a single collective.
``banded``
    Cyclic-band decomposition (``BandedPhi``): each nonzero band is one
    cyclic shift, so ring / TDMA-matching schedules (degree <= 2) pay
    O(degree) collectives.  Single-device lowering is ``jnp.roll``.
``ppermute``
    The same bands lowered to ``lax.ppermute`` neighbor exchanges under
    ``shard_map`` on a node-axis device mesh (``PermutePhi``): each band is
    ONE collective-permute of the local shard, so the O(degree) win shows up
    in wire bytes on real hardware, not just host timings.
``compressed``
    Wraps ANY inner backend: payloads ride the inner wire format int-
    quantized with a CHOCO-style error-feedback residual
    (``core.compression``), cutting bytes by ``32 / bits``.  Stateful — the
    driven algorithm must thread a mix state (``Algorithm.init_mix_state``).

``"auto"`` (the ``runner.run`` default) picks by mesh availability first,
then schedule bandwidth: a node-axis mesh (axis of size m) -> ``ppermute``
— even for a dense-saturated offset union, since on a mesh every band is
one collective-permute of the local shard (all-gathering m stacked copies
would be strictly worse); no mesh + banded structure (offset union
strictly smaller than m) -> ``banded``; no mesh + saturated union (e.g.
faithful unbounded multi-consensus, whose k-round products acquire
bandwidth k) -> ``dense``.  On the auto path the old band-saturation
``RuntimeWarning`` is thus replaced by a silent correct choice; EXPLICITLY
requesting ``banded`` on a saturated schedule still warns (correct, but
strictly slower than dense).

Methods that quantize their own gossip payload declare it via
``AlgoMeta.compress_bits``; the runner wraps whatever transport resolves in
a :class:`CompressedBackend` at those bits, so the ``wire_bytes`` accounting
always reflects what actually moves.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import compression, gossip, graphs

__all__ = [
    "TransportMeta",
    "band_offset_union",
    "GossipBackend",
    "DenseBackend",
    "BandedBackend",
    "PPermuteBackend",
    "CompressedBackend",
    "GOSSIP_BACKENDS",
    "select_backend_name",
    "resolve_backend",
    "node_param_count",
    "mix_matrix",
    "batch_phis",
]

F32_BYTES = 4


# ---------------------------------------------------------------------------
# The static slice of AlgoMeta a transport needs
# ---------------------------------------------------------------------------

class TransportMeta(NamedTuple):
    """What ``prepare`` needs to know about the driven loop: which
    ``rounds`` values the gossip policy will request.  ``AlgoMeta`` is
    duck-compatible (the runner passes it directly); loops without an
    AlgoMeta (the LM trainer) build one via :meth:`constant`."""
    outer_lengths: tuple | None
    num_steps: int | None
    gossip_rounds: Callable[[int], int]

    @classmethod
    def constant(cls, rounds: int) -> "TransportMeta":
        """A fixed-rounds gossip policy (the LM trainer's shape).  One probe
        step suffices: the rounds-value set is the singleton {rounds}, so
        num_steps=1 keeps ``band_offset_union`` from walking a long loop."""
        return cls(None, 1, lambda k: rounds)


def _rounds_values(meta) -> list[int]:
    if meta.outer_lengths is not None:
        ks = range(1, max(meta.outer_lengths) + 1)
    else:
        ks = range(1, (meta.num_steps or 1) + 1)
    return sorted({meta.gossip_rounds(k) for k in ks})


def band_offset_union(schedule: graphs.MixingSchedule, meta) -> tuple:
    """The static band-offset union a compiled banded step must support:
    offsets of every `rounds`-product the schedule can produce, for every
    rounds value the gossip policy will request.  Early-exits once the union
    saturates at m offsets (no structure left to exploit).

    Computed on ``schedule.structure_schedule``: an aperiodic scenario
    wrapper only ever removes edges from its base schedule, and supports of
    products of nonnegative matrices are monotone in the factor supports, so
    the base schedule's (finitely enumerable) union is a valid superset for
    every degraded realization."""
    schedule = schedule.structure_schedule
    m = schedule.m
    offs: set = set()
    for rounds in _rounds_values(meta):
        offs.update(gossip.schedule_band_offsets(schedule, rounds))
        if len(offs) >= m:
            break
    return tuple(sorted(offs))


def _phi_key(schedule: graphs.MixingSchedule, slot: int, rounds: int):
    """Memoization key for a per-slot wire representation.

    Periodic schedules repeat every ``period`` slots, so steady-state steps
    hit the cache; aperiodic (scenario-degraded) schedules key on the
    absolute slot — every step's realized product is cached under its own
    key, which is still a win for repeated runs over the same aux."""
    if schedule.aperiodic:
        return (slot, rounds)
    return (slot % schedule.period, rounds)


def node_param_count(tree) -> int:
    """Per-node parameter count of a stacked pytree (leaves (m, ...))."""
    return sum(int(np.prod(leaf.shape[1:], dtype=np.int64))
               for leaf in jax.tree.leaves(tree))


def mix_matrix(phi):
    """Lower a wire representation to the dense (m, m) mixing matrix the
    fused resident-step kernel consumes, or ``None`` when no static
    single-device lowering exists.

    Trace-safe: called inside compiled chunk bodies on ``lax.scan``-sliced
    phis, so both branches of the return may be tracers.  ``None`` means
    the caller must keep the unfused step: ``PermutePhi`` mixes via mesh
    collectives (the stacked buffer never exists on one device), compressed
    and scenario wrappers thread mix state, and stateful-only phi types are
    rejected wholesale.
    """
    if isinstance(phi, gossip.BandedPhi):
        return gossip.banded_to_dense(phi.offsets, phi.coeffs)
    if isinstance(phi, gossip.PermutePhi):
        return None
    if isinstance(phi, compression.CompressedPhi):
        return None
    if gossip._STATEFUL_ONLY and isinstance(phi, gossip._STATEFUL_ONLY):
        return None
    # dense (m, m) arrays and their in-trace tracer slices
    if getattr(phi, "ndim", None) == 2:
        return jnp.asarray(phi, jnp.float32)
    return None


def batch_phis(phis: "list") -> Any:
    """Stack per-cell wire representations along a new leading CELL axis —
    the batched-sweep staging primitive (the runner's chunk stacking then
    prepends the time axis, giving (T, B, ...) phi leaves that a vmapped
    chunk executor slices per cell).

    Every phi must share its pytree STRUCTURE including static aux data
    (same ``BandedPhi`` offset union, same ``PermutePhi`` mesh/axis): the
    compiled step specializes on the aux, so cells gossiping over
    structurally different wire formats cannot ride one batched program —
    the clear error here is what the sweep driver surfaces for such ragged
    grids (use ``gossip="dense"``, whose (m, m) wire format is structure-
    free, to batch across arbitrary topologies).  Leaf dtypes are
    preserved (integer quantized payloads must not widen to f32)."""
    defs = {str(jax.tree.structure(p)) for p in phis}
    if len(defs) > 1:
        raise ValueError(
            f"cannot batch gossip wire representations with different "
            f"static structure across sweep cells: {sorted(defs)}; cells "
            f"whose schedules decompose into different band/permute "
            f"structures need gossip='dense' to share one batched program")
    return jax.tree.map(
        lambda *leaves: np.stack([np.asarray(l) for l in leaves]), *phis)


def _active_entries(offsets: tuple, coeffs, m: int) -> Iterator:
    """(band offset d, node i) pairs whose coefficient actually carries mass
    this step: node i receives ``x_{(i+d) mod m}`` with weight coeffs[b][i].

    Per-ENTRY (not whole-band) so links a failure model dropped at this step
    — whose Metropolis reweighting zeroes exactly those coefficients — are
    not charged."""
    c = np.asarray(coeffs)
    for b, d in enumerate(offsets):
        if d % m == 0:
            continue
        for i in np.flatnonzero(np.abs(c[b]) > 1e-12):
            yield d, int(i)


def _banded_wire_bytes(offsets: tuple, coeffs, m: int,
                       param_count: int) -> int:
    """Point-to-point accounting for band-structured gossip: each nonzero
    off-diagonal coefficient moves one param vector over one link."""
    n = sum(1 for _ in _active_entries(offsets, coeffs, m))
    return n * param_count * F32_BYTES


def _banded_link_bytes(offsets: tuple, coeffs, m: int,
                       param_count: int) -> dict:
    """Per-directed-link refinement of :func:`_banded_wire_bytes`: band
    ``d`` at node ``i`` means one param vector moves over the link
    ``(i+d) mod m -> i``."""
    links: dict = {}
    for d, i in _active_entries(offsets, coeffs, m):
        key = ((i + d) % m, i)
        links[key] = links.get(key, 0) + param_count * F32_BYTES
    return links


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class GossipBackend:
    """Protocol base.  Instances are stateless/reusable; all per-run state
    lives in the ``aux`` returned by :meth:`prepare`.  ``needs_mix_state``
    marks stateful transports (error feedback): the runner asks the driven
    algorithm to thread the state via ``Algorithm.init_mix_state``."""

    name: str = "?"
    needs_mix_state: bool = False

    def prepare(self, schedule: graphs.MixingSchedule, meta, *,
                mesh=None) -> Any:
        raise NotImplementedError

    def phi_for(self, aux, slot: int, rounds: int):
        """Host-side wire representation of the ``rounds``-product starting
        at schedule slot ``slot`` (a pytree; scan-stackable).  Memoized in
        ``aux`` on ``(slot % period, rounds)`` — products of a periodic
        schedule repeat, so steady-state steps cost a dict lookup."""
        raise NotImplementedError

    def mix(self, aux, phi, tree):
        """Apply one mixing — identical to ``gossip.mix_stacked(phi, tree)``
        for stateless backends (the dispatch algorithm steps rely on)."""
        return gossip.mix_stacked(phi, tree)

    def init_mix_state(self, aux, x0):
        """Per-run transport state threaded through the algorithm state
        (``needs_mix_state`` backends only).  ``x0`` is the stacked initial
        iterate — the state the first mix sees."""
        raise NotImplementedError(
            f"gossip backend {self.name!r} is stateless (needs_mix_state="
            f"{self.needs_mix_state})")

    def bytes_per_step(self, aux, phi, param_count: int) -> int:
        """Wire bytes this step's mix moves across node links."""
        raise NotImplementedError

    def bytes_per_link(self, aux, phi, param_count: int) -> dict:
        """``{(src, dst): bytes}`` over directed node links for this step's
        mix — the per-edge refinement of :meth:`bytes_per_step` (values sum
        exactly to it), for topology-aware communication plots."""
        raise NotImplementedError


class _DenseAux(NamedTuple):
    schedule: graphs.MixingSchedule
    m: int
    cache: dict


class DenseBackend(GossipBackend):
    """One pre-multiplied ``(m, m)`` contraction per step."""

    name = "dense"

    def prepare(self, schedule, meta, *, mesh=None):
        return _DenseAux(schedule, schedule.m, {})

    def phi_for(self, aux, slot, rounds):
        key = _phi_key(aux.schedule, slot, rounds)
        phi = aux.cache.get(key)
        if phi is None:
            phi = aux.cache[key] = aux.schedule.consensus_rounds(slot, rounds)
        return phi

    def bytes_per_step(self, aux, phi, param_count):
        # the dense einsum lowers to an all-gather of the full stacked
        # buffer: every node receives the other m - 1 copies, regardless of
        # the product's sparsity
        return aux.m * (aux.m - 1) * param_count * F32_BYTES

    def bytes_per_link(self, aux, phi, param_count):
        return {(j, i): param_count * F32_BYTES
                for i in range(aux.m) for j in range(aux.m) if i != j}


class _BandedAux(NamedTuple):
    schedule: graphs.MixingSchedule
    m: int
    offsets: tuple
    cache: dict


class BandedBackend(GossipBackend):
    """Cyclic-band decomposition on the schedule's static offset union."""

    name = "banded"

    def prepare(self, schedule, meta, *, mesh=None):
        offsets = band_offset_union(schedule, meta)
        if len(offsets) >= schedule.m:
            # only reachable when banded was requested EXPLICITLY ("auto"
            # picks dense on a saturated union): still correct, but m
            # cyclic passes per step are strictly slower than one (m, m)
            # contraction
            warnings.warn(
                f"{schedule.name}: banded gossip needs all {len(offsets)} "
                f"of {schedule.m} band offsets — no O(degree) structure to "
                f"exploit; gossip='auto' or 'dense' will be faster (cap "
                f"multi-consensus rounds, e.g. k_max, to keep products "
                f"banded)", RuntimeWarning, stacklevel=3)
        return _BandedAux(schedule, schedule.m, offsets, {})

    def phi_for(self, aux, slot, rounds):
        key = _phi_key(aux.schedule, slot, rounds)
        phi = aux.cache.get(key)
        if phi is None:
            phi = aux.cache[key] = gossip.BandedPhi.from_dense(
                aux.schedule.consensus_rounds(slot, rounds), aux.offsets)
        return phi

    def bytes_per_step(self, aux, phi, param_count):
        return _banded_wire_bytes(phi.offsets, phi.coeffs, aux.m, param_count)

    def bytes_per_link(self, aux, phi, param_count):
        return _banded_link_bytes(phi.offsets, phi.coeffs, aux.m, param_count)


class _PermuteAux(NamedTuple):
    schedule: graphs.MixingSchedule
    m: int
    offsets: tuple
    mesh: Any
    axis: str
    cache: dict


def _node_axis(mesh, m: int) -> str | None:
    """The mesh axis carrying one node per device, if any."""
    for axis, size in mesh.shape.items():
        if size == m:
            return axis
    return None


class PPermuteBackend(GossipBackend):
    """Banded gossip lowered to ``lax.ppermute`` under ``shard_map``.

    Needs a mesh with a node axis of size m (one node per device along that
    axis).  When ``mesh`` is None, builds a 1-D ``("nodes",)`` mesh over the
    first m local devices — raising with an ``XLA_FLAGS`` hint when the
    process has fewer.
    """

    name = "ppermute"

    def prepare(self, schedule, meta, *, mesh=None):
        m = schedule.m
        if mesh is None:
            devices = jax.devices()
            if len(devices) < m:
                raise ValueError(
                    f"ppermute gossip needs a mesh with a node axis of size "
                    f"{m}, but only {len(devices)} device(s) are visible "
                    f"(force a host-platform mesh with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={m}, or pass "
                    f"mesh=)")
            mesh = jax.make_mesh((m,), ("nodes",),
                                 devices=np.array(devices[:m]))
            axis = "nodes"
        else:
            axis = _node_axis(mesh, m)
            if axis is None:
                raise ValueError(
                    f"mesh {dict(mesh.shape)} has no axis of size m={m} to "
                    f"carry the node dimension")
        return _PermuteAux(schedule, m, band_offset_union(schedule, meta),
                           mesh, axis, {})

    def phi_for(self, aux, slot, rounds):
        key = _phi_key(aux.schedule, slot, rounds)
        phi = aux.cache.get(key)
        if phi is None:
            phi = aux.cache[key] = gossip.PermutePhi.from_dense(
                aux.schedule.consensus_rounds(slot, rounds), aux.offsets,
                aux.mesh, aux.axis)
        return phi

    def bytes_per_step(self, aux, phi, param_count):
        return _banded_wire_bytes(phi.offsets, phi.coeffs, aux.m, param_count)

    def bytes_per_link(self, aux, phi, param_count):
        return _banded_link_bytes(phi.offsets, phi.coeffs, aux.m, param_count)


class _CompressedAux(NamedTuple):
    inner_backend: GossipBackend
    inner_aux: Any
    bits: int


@dataclasses.dataclass(frozen=True)
class CompressedBackend(GossipBackend):
    """Error-feedback quantized gossip over ANY inner wire format.

    ``inner`` names (or is) the transport the quantized payload rides on;
    ``bits`` the integer width.  Stateful: the residual accumulator threads
    through the algorithm state (``Algorithm.init_mix_state``), so only
    algorithms that support a mix state (DPSVRG, GT-SVRG, loopless DPSVRG)
    can be driven compressed.
    """

    inner: Any = "dense"   # str name or GossipBackend instance
    bits: int = 8

    name = "compressed"
    needs_mix_state = True

    def _inner_backend(self) -> GossipBackend:
        if isinstance(self.inner, str):
            if self.inner == "compressed":
                raise ValueError("compressed cannot wrap itself")
            return GOSSIP_BACKENDS[self.inner]
        return self.inner

    def prepare(self, schedule, meta, *, mesh=None):
        ib = self._inner_backend()
        return _CompressedAux(ib, ib.prepare(schedule, meta, mesh=mesh),
                              self.bits)

    def phi_for(self, aux, slot, rounds):
        return compression.CompressedPhi(
            aux.inner_backend.phi_for(aux.inner_aux, slot, rounds), aux.bits)

    def init_mix_state(self, aux, x0) -> compression.CompressionState:
        return compression.init_state(x0)

    def mix(self, aux, phi, tree, mix_state=None):
        """Stateful mix: returns ``(mixed, new_state)``."""
        if mix_state is None:
            raise ValueError("compressed gossip needs an error-feedback "
                             "state (see compression.init_state)")
        return compression.mix_with_state(phi, tree, mix_state)

    def bytes_per_step(self, aux, phi, param_count):
        inner = aux.inner_backend.bytes_per_step(aux.inner_aux, phi.inner,
                                                 param_count)
        return inner * aux.bits // 32

    def bytes_per_link(self, aux, phi, param_count):
        # per-link floors can undershoot the single-floor total
        # (bytes_per_step) when bits doesn't divide 32 evenly; distribute
        # the rounding remainder deterministically so the map still sums
        # EXACTLY to bytes_per_step (the documented invariant)
        inner = aux.inner_backend.bytes_per_link(aux.inner_aux, phi.inner,
                                                 param_count)
        links = {link: b * aux.bits // 32 for link, b in inner.items()}
        remainder = (self.bytes_per_step(aux, phi, param_count)
                     - sum(links.values()))
        for link in sorted(links):
            if remainder <= 0:
                break
            links[link] += 1
            remainder -= 1
        return links


# ---------------------------------------------------------------------------
# Registry + "auto" selection
# ---------------------------------------------------------------------------

GOSSIP_BACKENDS: dict[str, GossipBackend] = {
    "dense": DenseBackend(),
    "banded": BandedBackend(),
    "ppermute": PPermuteBackend(),
    "compressed": CompressedBackend(),
}


def select_backend_name(schedule: graphs.MixingSchedule, meta,
                        mesh=None) -> str:
    """The ``"auto"`` rule.

    A node-axis mesh (an axis of size m) wins outright -> ``"ppermute"``:
    on a real mesh every band is one collective-permute of the LOCAL shard
    regardless of how many bands the union holds, so even a dense-saturated
    union (which historically forced ``"dense"`` and silently ignored the
    mesh) moves O(m) local payloads per step instead of all-gathering m
    stacked copies to every node.  Otherwise: banded structure present
    (static offset union strictly smaller than m) -> ``"banded"``; saturated
    union (e.g. faithful DPSVRG multi-consensus, whose unbounded k-round
    products acquire bandwidth k) -> ``"dense"``: m cyclic passes per step
    on ONE device would be strictly slower than one (m, m) contraction, so
    the old band-saturation ``RuntimeWarning`` is now just the dense choice.
    """
    if mesh is not None and _node_axis(mesh, schedule.m) is not None:
        return "ppermute"
    if len(band_offset_union(schedule, meta)) >= schedule.m:
        return "dense"
    return "banded"


def resolve_backend(gossip, schedule: graphs.MixingSchedule, meta,
                    mesh=None) -> GossipBackend:
    """``gossip`` is a registry name, ``"auto"``, or a backend instance."""
    if isinstance(gossip, str):
        name = (select_backend_name(schedule, meta, mesh)
                if gossip == "auto" else gossip)
        try:
            return GOSSIP_BACKENDS[name]
        except KeyError:
            raise ValueError(
                f"unknown gossip backend {gossip!r}: expected 'auto', one of "
                f"{sorted(GOSSIP_BACKENDS)}, or a GossipBackend instance"
            ) from None
    return gossip
