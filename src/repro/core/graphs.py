"""Time-varying communication graphs and doubly-stochastic mixing matrices.

Implements the paper's network model (Section II-A):

* b-connected time-varying graph sequences (Assumption 1): the union of any
  ``b`` consecutive edge sets is connected.
* Doubly-stochastic mixing matrices ``W^t`` (Assumption 2) with a uniform
  positive lower bound ``eta`` on nonzero entries.
* The aggregated communication matrix ``Phi(l, g) = W^g ... W^l`` and the
  Lemma-1 geometric-contraction constants ``Gamma = 2(1 + eta^{-b0})``,
  ``gamma = 1 - eta^{b0}`` with ``b0 = (m - 1) b``.

All matrices are plain ``numpy`` float64 on host: mixing schedules are
precomputed outside the jitted step (they are tiny, m <= a few dozen) and fed
to the device either as a single multi-consensus product or as ring
decomposition weights.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Sequence

import numpy as np

__all__ = [
    "MixingSchedule",
    "metropolis_weights",
    "ring_matrix",
    "fully_connected_matrix",
    "exponential_graph_matrices",
    "edge_matching_matrices",
    "b_connected_ring_schedule",
    "random_b_connected_schedule",
    "static_schedule",
    "is_doubly_stochastic",
    "spectral_gap",
    "second_largest_singular_value",
    "lemma1_constants",
    "phi_product",
    "consensus_distance",
]


# ---------------------------------------------------------------------------
# Matrix constructors
# ---------------------------------------------------------------------------

def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings doubly-stochastic weights for an undirected graph.

    ``w_ij = 1 / (1 + max(deg_i, deg_j))`` for edges, self-weight takes the
    remainder.  Always symmetric and doubly stochastic; nonzero entries are
    bounded below by ``1 / (1 + max_deg)`` (Assumption 2's ``eta``).
    """
    adj = np.asarray(adj, dtype=bool)
    m = adj.shape[0]
    adj = adj & ~np.eye(m, dtype=bool)  # no self loops in adjacency
    deg = adj.sum(axis=1)
    w = np.zeros((m, m), dtype=np.float64)
    for i in range(m):
        for j in range(i + 1, m):
            if adj[i, j]:
                w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    w[np.arange(m), np.arange(m)] = 1.0 - w.sum(axis=1)
    return w


def ring_matrix(m: int, self_weight: float = 1.0 / 3.0) -> np.ndarray:
    """Symmetric ring gossip matrix: each node averages with both neighbors."""
    if m == 1:
        return np.ones((1, 1))
    if m == 2:
        return np.full((2, 2), 0.5)
    w = np.eye(m) * self_weight
    side = (1.0 - self_weight) / 2.0
    for i in range(m):
        w[i, (i + 1) % m] = side
        w[i, (i - 1) % m] = side
    return w


def fully_connected_matrix(m: int) -> np.ndarray:
    return np.full((m, m), 1.0 / m)


def exponential_graph_matrices(m: int) -> list[np.ndarray]:
    """One-peer exponential graph family: at slot t each node talks to the
    peer ``2^t`` hops away.  Each matrix is a disjoint pairwise averaging
    (doubly stochastic); the family over ``ceil(log2 m)`` slots is connected,
    so the sequence is b-connected with ``b = ceil(log2 m)``.
    """
    mats = []
    hops = 1
    while hops < m:
        w = np.zeros((m, m))
        paired = np.zeros(m, dtype=bool)
        for i in range(m):
            j = (i + hops) % m
            if not paired[i] and not paired[j] and i != j:
                w[i, j] = w[j, i] = 0.5
                w[i, i] = w[j, j] = 0.5
                paired[i] = paired[j] = True
        for i in range(m):
            if not paired[i]:
                w[i, i] = 1.0
        mats.append(w)
        hops *= 2
    return mats or [np.ones((1, 1))]


def edge_matching_matrices(m: int) -> list[np.ndarray]:
    """Edge matchings of a ring: disjoint-pair matrices whose union is the
    full ring.

    Models TDMA-style link activation (only non-interfering links are active
    simultaneously) — the paper's motivating time-varying scenario.  For even
    m the even/odd matchings cover all m ring edges, so the sequence is
    b-connected with b = 2.  For odd m the closing edge (m-1, 0) conflicts
    with BOTH matchings (node 0 is matched in the even one, node m-1 in the
    odd one), so a third matching carries it and b = 3.  (Before this fix
    the closing edge was silently dropped for odd m: the union degenerated
    from the advertised ring to a path, whose far-end nodes only exchange
    information through every intermediate hop — a strictly weaker topology
    than claimed, with a correspondingly worse Lemma-1 contraction.)  Use
    ``b = len(result)``.
    """
    even = np.eye(m)
    odd = np.eye(m)
    for i in range(0, m - 1, 2):
        even[i, i] = even[i + 1, i + 1] = 0.5
        even[i, i + 1] = even[i + 1, i] = 0.5
    for i in range(1, m - 1, 2):
        odd[i, i] = odd[i + 1, i + 1] = 0.5
        odd[i, i + 1] = odd[i + 1, i] = 0.5
    mats = [even, odd]
    if m > 2:
        if m % 2 == 0:
            # close the ring in the odd matching (0 and m-1 are both free)
            odd[0, 0] = odd[m - 1, m - 1] = 0.5
            odd[0, m - 1] = odd[m - 1, 0] = 0.5
        else:
            closing = np.eye(m)
            closing[0, 0] = closing[m - 1, m - 1] = 0.5
            closing[0, m - 1] = closing[m - 1, 0] = 0.5
            mats.append(closing)
    return mats


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MixingSchedule:
    """A periodic sequence of doubly-stochastic mixing matrices.

    ``matrix(t)`` returns ``W^t``; ``phi(l, g)`` returns the aggregated
    product ``W^g @ ... @ W^l`` used by multi-consensus (host-side, so a
    k-round consensus costs a single device collective).
    """

    matrices: tuple  # tuple[np.ndarray, ...]
    b: int           # connectivity window (Assumption 1)
    eta: float       # entry lower bound (Assumption 2)
    name: str = "schedule"

    @property
    def m(self) -> int:
        return self.matrices[0].shape[0]

    @property
    def period(self) -> int:
        return len(self.matrices)

    @property
    def aperiodic(self) -> bool:
        """True when ``matrix(t)`` is NOT a pure function of ``t % period``.

        Transport caches key their per-slot phi products on
        ``slot % period`` only when this is False; scenario wrappers that
        degrade matrices per absolute step override this.
        """
        return False

    @property
    def structure_schedule(self) -> "MixingSchedule":
        """Schedule whose sparsity pattern bounds this one's (self here).

        Scenario wrappers return their base schedule: a degraded matrix only
        ever REMOVES edges, and supports of products of nonnegative matrices
        are monotone in the factor supports, so band/offset unions computed
        on the base schedule are valid (superset) for the wrapper.
        """
        return self

    def matrix(self, t: int) -> np.ndarray:
        return self.matrices[t % self.period]

    def phi(self, l: int, g: int) -> np.ndarray:
        """Phi(l, g) = W^g W^{g-1} ... W^l (inclusive), Eq. before Lemma 1."""
        out = np.eye(self.m)
        for t in range(l, g + 1):
            out = self.matrix(t) @ out
        return out

    def consensus_rounds(self, t0: int, rounds: int) -> np.ndarray:
        """Product of ``rounds`` consecutive matrices starting at slot t0."""
        if rounds <= 0:
            return np.eye(self.m)
        return self.phi(t0, t0 + rounds - 1)

    def iter_matrices(self, start: int = 0) -> Iterator[np.ndarray]:
        t = start
        while True:
            yield self.matrix(t)
            t += 1


def _as_rng(seed) -> np.random.Generator:
    """Accept either an int seed or an already-constructed Generator.

    Passing a Generator lets callers keep schedule randomness on a stream
    that cannot alias a scenario/failure stream built from the same int.
    """
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(seed)


def static_schedule(w: np.ndarray, name: str = "static") -> MixingSchedule:
    eta = float(w[w > 1e-12].min()) if (w > 1e-12).any() else 0.0
    return MixingSchedule(matrices=(w,), b=1, eta=eta, name=name)


def b_connected_ring_schedule(m: int, b: int,
                              seed: "int | np.random.Generator" = 0,
                              ) -> MixingSchedule:
    """Paper Section V-D: a set of ``b`` doubly-stochastic matrices such that
    only the union of all ``b`` of them is connected; matrices are cycled
    periodically, so the sequence is b-connected.

    Construction: partition the ring's m edges into ``b`` groups; slot t
    activates group ``t mod b`` as a disjoint-pair averaging (plus self
    loops).  With b = 1 this degenerates to the full ring matrix.
    """
    if b <= 1:
        return static_schedule(ring_matrix(m), name=f"ring{m}")
    rng = _as_rng(seed)
    edges = [(i, (i + 1) % m) for i in range(m)]
    order = list(rng.permutation(m))
    # Greedy matching partition: place every ring edge into one of the b
    # slots such that each slot stays a disjoint matching.  A cycle has max
    # degree 2, so b >= 2 slots always suffice (add extra slots never hurts:
    # all m edges MUST be placed or the union is not connected).
    groups: list[list[tuple[int, int]]] = [[] for _ in range(b)]
    used = [set() for _ in range(b)]
    for idx in order:
        i, j = edges[idx]
        placed = False
        for g in range(b):
            gg = (idx + g) % b
            if i not in used[gg] and j not in used[gg]:
                groups[gg].append((i, j))
                used[gg].update((i, j))
                placed = True
                break
        if not placed:  # degenerate tiny-m case: widen slot 0 beyond a matching
            groups[idx % b].append((i, j))
            used[idx % b].update((i, j))
    mats = []
    for grp in groups:
        adj = np.zeros((m, m), dtype=bool)
        for (i, j) in grp:
            adj[i, j] = adj[j, i] = True
        mats.append(metropolis_weights(adj))
    eta = min(float(w[w > 1e-12].min()) for w in mats)
    return MixingSchedule(matrices=tuple(mats), b=b, eta=eta,
                          name=f"bring{m}_b{b}")


def random_b_connected_schedule(m: int, b: int, p_keep: float = 0.5,
                                seed: "int | np.random.Generator" = 0,
                                ) -> MixingSchedule:
    """Random time-varying graphs: each slot keeps a random subset of a base
    connected graph's edges; every b-th slot inserts the full ring to
    guarantee b-connectivity.  Metropolis weights keep double stochasticity.

    ``seed`` may be an int or an ``np.random.Generator`` (the latter keeps
    schedule draws on a stream disjoint from scenario-event streams).
    """
    rng = _as_rng(seed)
    mats = []
    for t in range(b):
        adj = np.zeros((m, m), dtype=bool)
        if t == b - 1:
            for i in range(m):
                adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = True
        else:
            for i in range(m):
                j = (i + 1) % m
                if rng.random() < p_keep:
                    adj[i, j] = adj[j, i] = True
        mats.append(metropolis_weights(adj))
    eta = min(float(w[w > 1e-12].min()) for w in mats)
    return MixingSchedule(matrices=tuple(mats), b=b, eta=eta,
                          name=f"rand{m}_b{b}")


# ---------------------------------------------------------------------------
# Analysis helpers
# ---------------------------------------------------------------------------

def is_doubly_stochastic(w: np.ndarray, atol: float = 1e-9) -> bool:
    m = w.shape[0]
    ones = np.ones(m)
    return (np.all(w >= -atol)
            and np.allclose(w @ ones, ones, atol=atol)
            and np.allclose(w.T @ ones, ones, atol=atol))


def second_largest_singular_value(w: np.ndarray) -> float:
    s = np.linalg.svd(w, compute_uv=False)
    return float(s[1]) if len(s) > 1 else 0.0


def spectral_gap(w: np.ndarray) -> float:
    """1 - |sigma_2(W)|; larger gap → faster consensus."""
    return 1.0 - second_largest_singular_value(w)


def lemma1_constants(schedule: MixingSchedule) -> tuple[float, float]:
    """Lemma 1 constants (Gamma, gamma): |phi_ij(l,g) - 1/m| <= Gamma*gamma^{g-l}."""
    m = schedule.m
    b0 = (m - 1) * schedule.b
    eta = schedule.eta
    gamma = 1.0 - eta ** b0
    big_gamma = 2.0 * (1.0 + eta ** (-b0))
    return big_gamma, gamma


def phi_product(mats: Sequence[np.ndarray]) -> np.ndarray:
    """W^g ... W^l for mats = [W^l, ..., W^g]."""
    out = np.eye(mats[0].shape[0])
    for w in mats:
        out = w @ out
    return out


def consensus_distance(x_stacked) -> float:
    """Mean L2 distance of node copies from their average (host metric)."""
    x = np.asarray(x_stacked)
    xbar = x.mean(axis=0, keepdims=True)
    return float(np.mean(np.linalg.norm((x - xbar).reshape(x.shape[0], -1), axis=1)))
