"""The paper's contribution: DPSVRG and its supporting decentralized machinery.

Submodules:
  graphs     — time-varying b-connected doubly-stochastic mixing schedules
  prox       — closed-form proximal operators (l1, elastic net, group lasso, ...)
  svrg       — variance-reduced gradient estimator + snapshot state
  gossip     — consensus over stacked node parameters (einsum & ppermute paths)
  dpsvrg     — Algorithm 1 + DSPG baseline + centralized prox-GD reference
  inexact    — Algorithm 2 (Inexact Prox-SVRG) + executable Theorem 1
  schedules  — K_s growth, DSPG decaying steps, WSD / cosine LR schedules
"""

from . import dpsvrg, gossip, graphs, inexact, prox, schedules, svrg

__all__ = ["dpsvrg", "gossip", "graphs", "inexact", "prox", "schedules", "svrg"]
