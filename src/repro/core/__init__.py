"""The paper's contribution: DPSVRG and its supporting decentralized machinery.

Submodules:
  graphs     — time-varying b-connected doubly-stochastic mixing schedules
  prox       — closed-form proximal operators (l1, elastic net, group lasso, ...)
  svrg       — variance-reduced gradient estimator + snapshot state
  gossip     — consensus over stacked node parameters (dense einsum, cyclic
               bands, shard_map ppermute)
  transport  — the pluggable `GossipBackend` wire formats (dense / banded /
               ppermute / compressed), "auto" selection, wire-byte accounting
  algorithm  — the unified `DecentralizedAlgorithm` protocol + all methods
  exec_spec  — `ExecSpec`: the one immutable execution specification
               (path / sampling / kernel / transport / mesh / shard)
               consumed by runner.run, run_sweep, and train_loop
  runner     — the single generic driver (host loop, lax.scan fast path,
               and the device-resident path: one staged transfer per run,
               donated carries, on-device metric recording; pluggable
               gossip transports, bucketed chunk compilation, persistent
               executable cache)
  dpsvrg     — Algorithm 1 hyper-params / step builders + centralized prox-GD
  inexact    — Algorithm 2 (Inexact Prox-SVRG) on the protocol + executable
               Theorem 1 (registered as ALGORITHMS["inexact_prox_svrg"])
  schedules  — K_s growth, DSPG decaying steps, WSD / cosine LR schedules

The Algorithm protocol (``core.algorithm``)
-------------------------------------------
Every decentralized method is three pure transitions over an
algorithm-private state pytree (stacked node params, leading axis m):

    algo.init()                      -> state    all nodes at x0
    algo.step(state, batch, phi, a)  -> state    one inner iteration
    algo.outer(state)                -> state    snapshot / full-grad refresh
    algo.end_outer(state, K)         -> state    close an inner round

plus declarative ``AlgoMeta`` (loop structure, grad-evals per step, gossip
rounds policy, metric conventions).  ``runner.run(algo, problem, schedule)``
owns batch sampling, time-varying gossip scheduling, epoch/communication
accounting, pluggable metric recorders, and an optional ``lax.scan`` fast
path that executes a whole record interval in one device dispatch.  Adding a
baseline = writing a factory in ``core.algorithm`` and registering it in
``algorithm.ALGORITHMS``; it then runs on every problem, schedule, benchmark
figure, and recorder in the repo.  The LM trainer (``repro.train``) builds
its jitted step from the same ``UPDATE_RULES`` + ``prox_gossip_update``, so
paper-scale repro and LM-scale training share one update implementation.
"""

from . import (algorithm, dpsvrg, exec_spec, gossip, graphs, inexact, prox,
               runner, schedules, svrg, sweep, transport)
from .exec_spec import ExecSpec

__all__ = ["algorithm", "dpsvrg", "exec_spec", "ExecSpec", "gossip",
           "graphs", "inexact", "prox", "runner", "schedules", "svrg",
           "sweep", "transport"]
