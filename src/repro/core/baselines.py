"""Decentralized baselines beyond DSPG — thin wrappers over the unified runner.

* DPG  — Decentralized Proximal Gradient [paper ref. 10]: full local
  gradients (no stochasticity), gossip, prox.  The deterministic anchor:
  smooth convergence, m x n gradient cost per step.
* GT-SVRG — gradient-tracking + SVRG (the paper's related work [18, 19],
  Network-SVRG / GT-SVRG family): each node maintains a tracker y_i of the
  global gradient direction,

      x_i <- prox( sum_j W_ij x_j - alpha * y_i )
      y_i <- sum_j W_ij y_j + v_i(x_new) - v_i(x_old)

  with v the SVRG-corrected local estimator.  Gradient tracking removes the
  bias from heterogeneous local objectives without multi-consensus — the
  natural head-to-head for DPSVRG on non-IID partitions.
* loopless DPSVRG — BEYOND-PAPER L-SVRG-style coin-flip snapshots.

All three are ``Algorithm`` plugins in ``repro.core.algorithm``; the
``*_run`` functions here are **deprecated** compatibility wrappers over
``repro.core.runner.run`` that reproduce the pre-refactor histories
seed-for-seed (see tests/test_algorithm_api.py).
"""

from __future__ import annotations

from typing import Callable

from . import graphs, prox as prox_lib, runner as runner_lib
from .algorithm import (Problem, dpg_algorithm, gt_svrg_algorithm,
                        loopless_dpsvrg_algorithm)

__all__ = ["dpg_run", "gt_svrg_run", "loopless_dpsvrg_run"]


def loopless_dpsvrg_run(loss_fn: Callable,
                        prox: prox_lib.Prox,
                        x0_stacked,
                        full_data,
                        schedule: graphs.MixingSchedule,
                        alpha: float,
                        num_steps: int,
                        snapshot_prob: float = 0.05,
                        consensus_rounds: int = 2,
                        batch_size: int = 1,
                        seed: int = 0,
                        record_every: int = 10,
                        objective_fn: Callable | None = None,
                        scan: bool = False):
    """Deprecated wrapper: loopless DPSVRG through the unified runner.

    Replaces Algorithm 1's growing inner loop K_s = ceil(beta^s n0) with a
    per-step coin flip: with probability p the snapshot/full gradient is
    refreshed at the CURRENT iterate.  Same expected epoch cost at
    p ~ batch/n, no outer-loop bookkeeping, and a fixed-shape step — much
    friendlier to a compiled production trainer than a geometrically
    growing loop (this is the variant the LM trainer's fixed
    ``snapshot_every`` approximates deterministically).
    """
    problem = Problem(loss_fn, prox, x0_stacked, full_data, objective_fn)
    algo = loopless_dpsvrg_algorithm(problem, alpha, num_steps,
                                     snapshot_prob=snapshot_prob,
                                     consensus_rounds=consensus_rounds,
                                     batch_size=batch_size)
    res = runner_lib.run(algo, problem, schedule, seed=seed,
                         record_every=record_every, scan=scan)
    return res.params, res.history


def dpg_run(loss_fn: Callable,
            prox: prox_lib.Prox,
            x0_stacked,
            full_data,
            schedule: graphs.MixingSchedule,
            alpha: float,
            num_steps: int,
            record_every: int = 10,
            objective_fn: Callable | None = None,
            scan: bool = False):
    """Deprecated wrapper: deterministic decentralized proximal gradient."""
    problem = Problem(loss_fn, prox, x0_stacked, full_data, objective_fn)
    algo = dpg_algorithm(problem, alpha, num_steps)
    res = runner_lib.run(algo, problem, schedule,
                         record_every=record_every, scan=scan)
    return res.params, res.history


def gt_svrg_run(loss_fn: Callable,
                prox: prox_lib.Prox,
                x0_stacked,
                full_data,
                schedule: graphs.MixingSchedule,
                alpha: float,
                num_outer: int,
                inner_steps: int,
                batch_size: int = 1,
                seed: int = 0,
                record_every: int = 0,
                objective_fn: Callable | None = None,
                scan: bool = False):
    """Deprecated wrapper: gradient-tracking SVRG through the unified runner.

    Outer rounds refresh the snapshot/full-gradient; inner steps do one
    gossip round each (no multi-consensus — tracking replaces it).
    """
    problem = Problem(loss_fn, prox, x0_stacked, full_data, objective_fn)
    algo = gt_svrg_algorithm(problem, alpha, num_outer, inner_steps,
                             batch_size=batch_size)
    res = runner_lib.run(algo, problem, schedule, seed=seed,
                         record_every=record_every, scan=scan)
    return res.params, res.history
