"""Additional decentralized baselines beyond DSPG.

* DPG  — Decentralized Proximal Gradient [paper ref. 10]: full local
  gradients (no stochasticity), gossip, prox.  The deterministic anchor:
  smooth convergence, m x n gradient cost per step.
* GT-SVRG — gradient-tracking + SVRG (the paper's related work [18, 19],
  Network-SVRG / GT-SVRG family): each node maintains a tracker y_i of the
  global gradient direction,

      x_i <- prox( sum_j W_ij x_j - alpha * y_i )
      y_i <- sum_j W_ij y_j + v_i(x_new) - v_i(x_old)

  with v the SVRG-corrected local estimator.  Gradient tracking removes the
  bias from heterogeneous local objectives without multi-consensus — the
  natural head-to-head for DPSVRG on non-IID partitions.

Both reuse the stacked-parameter layout, so they run on the same problems,
schedules, and metrics as core.dpsvrg (see benchmarks/baselines_compare.py).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import dpsvrg, gossip, graphs, prox as prox_lib, schedules, svrg

__all__ = ["dpg_run", "gt_svrg_run", "loopless_dpsvrg_run"]


def loopless_dpsvrg_run(loss_fn: Callable,
                        prox: prox_lib.Prox,
                        x0_stacked,
                        full_data,
                        schedule: graphs.MixingSchedule,
                        alpha: float,
                        num_steps: int,
                        snapshot_prob: float = 0.05,
                        consensus_rounds: int = 2,
                        batch_size: int = 1,
                        seed: int = 0,
                        record_every: int = 10,
                        objective_fn: Callable | None = None):
    """BEYOND-PAPER: loopless DPSVRG (L-SVRG-style).

    Replaces Algorithm 1's growing inner loop K_s = ceil(beta^s n0) with a
    per-step coin flip: with probability p the snapshot/full gradient is
    refreshed at the CURRENT iterate.  Same expected epoch cost at
    p ~ batch/n, no outer-loop bookkeeping, and a fixed-shape step — much
    friendlier to a compiled production trainer than a geometrically
    growing loop (this is the variant the LM trainer's fixed
    ``snapshot_every`` approximates deterministically).
    """
    rng = np.random.default_rng(seed)
    inner_step = dpsvrg.build_dpsvrg_inner_step(loss_fn, prox)
    full_grad_fn = dpsvrg.build_node_full_grad_fn(loss_fn, full_data)
    obj = objective_fn or (
        lambda p: dpsvrg._objective(loss_fn, prox, p, full_data))

    m = jax.tree.leaves(x0_stacked)[0].shape[0]
    n = jax.tree.leaves(full_data)[0].shape[1]
    params = x0_stacked
    state = svrg.SvrgState(snapshot=params, full_grad=full_grad_fn(params))
    grad_evals = m * n
    slot = 0
    hist_obj, hist_ep, hist_steps = [obj(params)], [grad_evals / (m * n)], [0]
    for t in range(1, num_steps + 1):
        batch = dpsvrg._sample_batch(rng, full_data, batch_size)
        phi = schedule.consensus_rounds(slot, consensus_rounds)
        slot += consensus_rounds
        params = inner_step(params, state, batch,
                            jnp.asarray(phi, jnp.float32), jnp.float32(alpha))
        grad_evals += 2 * m * batch_size
        if rng.random() < snapshot_prob:
            state = svrg.SvrgState(snapshot=params,
                                   full_grad=full_grad_fn(params))
            grad_evals += m * n
        if t % record_every == 0 or t == num_steps:
            hist_obj.append(obj(params))
            hist_ep.append(grad_evals / float(m * n))
            hist_steps.append(t)
    return params, dpsvrg.RunHistory(
        np.array(hist_obj), np.zeros(len(hist_obj)), np.array(hist_ep),
        np.array(hist_steps), np.array(hist_steps))


def dpg_run(loss_fn: Callable,
            prox: prox_lib.Prox,
            x0_stacked,
            full_data,
            schedule: graphs.MixingSchedule,
            alpha: float,
            num_steps: int,
            record_every: int = 10,
            objective_fn: Callable | None = None):
    """Deterministic decentralized proximal gradient."""
    full_grad_fn = dpsvrg.build_node_full_grad_fn(loss_fn, full_data)
    obj = objective_fn or (
        lambda p: dpsvrg._objective(loss_fn, prox, p, full_data))

    @jax.jit
    def step(params, w, a):
        g = full_grad_fn(params)
        q = jax.tree.map(lambda x, gi: x - a * gi, params, g)
        q_hat = gossip.mix_stacked(w, q)
        return prox.apply(q_hat, a)

    m = jax.tree.leaves(x0_stacked)[0].shape[0]
    n = jax.tree.leaves(full_data)[0].shape[1]
    params = x0_stacked
    hist_obj, hist_ep, hist_steps = [obj(params)], [0.0], [0]
    for t in range(1, num_steps + 1):
        params = step(params, jnp.asarray(schedule.matrix(t), jnp.float32),
                      jnp.float32(alpha))
        if t % record_every == 0 or t == num_steps:
            hist_obj.append(obj(params))
            hist_ep.append(float(t))           # one epoch per step (full grad)
            hist_steps.append(t)
    return params, dpsvrg.RunHistory(
        np.array(hist_obj), np.zeros(len(hist_obj)), np.array(hist_ep),
        np.array(hist_steps), np.array(hist_steps))


def gt_svrg_run(loss_fn: Callable,
                prox: prox_lib.Prox,
                x0_stacked,
                full_data,
                schedule: graphs.MixingSchedule,
                alpha: float,
                num_outer: int,
                inner_steps: int,
                batch_size: int = 1,
                seed: int = 0,
                record_every: int = 0,
                objective_fn: Callable | None = None):
    """Gradient-tracking SVRG over the same stacked layout.

    Outer rounds refresh the snapshot/full-gradient; inner steps do one
    gossip round each (no multi-consensus — tracking replaces it).
    """
    rng = np.random.default_rng(seed)
    node_grad = dpsvrg.build_node_grad_fn(loss_fn)
    full_grad_fn = dpsvrg.build_node_full_grad_fn(loss_fn, full_data)
    obj = objective_fn or (
        lambda p: dpsvrg._objective(loss_fn, prox, p, full_data))

    @jax.jit
    def inner(params, tracker, v_prev, state, batch, w, a):
        q = jax.tree.map(lambda x, y: x - a * y, params, tracker)
        q_hat = gossip.mix_stacked(w, q)
        new_params = prox.apply(q_hat, a)
        v_new = svrg.corrected_gradient(node_grad, new_params, state, batch)
        new_tracker = jax.tree.map(
            lambda ty, vn, vp: ty + vn - vp,
            gossip.mix_stacked(w, tracker), v_new, v_prev)
        return new_params, new_tracker, v_new

    m = jax.tree.leaves(x0_stacked)[0].shape[0]
    n = jax.tree.leaves(full_data)[0].shape[1]
    params = x0_stacked
    snapshot = x0_stacked
    hist_obj, hist_steps = [obj(params)], [0]
    t = 0
    grad_evals = 0
    hist_ep = [0.0]
    # initialize tracker with the snapshot full gradient (standard GT init)
    state = svrg.SvrgState(snapshot=snapshot,
                           full_grad=full_grad_fn(snapshot))
    tracker = state.full_grad
    v_prev = state.full_grad
    for s in range(num_outer):
        state = svrg.SvrgState(snapshot=snapshot,
                               full_grad=full_grad_fn(snapshot))
        grad_evals += m * n
        inner_sum = jax.tree.map(jnp.zeros_like, params)
        for k in range(inner_steps):
            batch = dpsvrg._sample_batch(rng, full_data, batch_size)
            w = jnp.asarray(schedule.matrix(t), jnp.float32)
            params, tracker, v_prev = inner(
                params, tracker, v_prev, state, batch, w, jnp.float32(alpha))
            inner_sum = svrg.tree_add(inner_sum, params)
            grad_evals += 2 * m * batch_size
            t += 1
            if record_every and t % record_every == 0:
                hist_obj.append(obj(params))
                hist_steps.append(t)
                hist_ep.append(grad_evals / float(m * n))
        snapshot = jax.tree.map(lambda acc: acc / inner_steps, inner_sum)
        if not record_every:
            hist_obj.append(obj(params))
            hist_steps.append(t)
            hist_ep.append(grad_evals / float(m * n))
    return params, dpsvrg.RunHistory(
        np.array(hist_obj), np.zeros(len(hist_obj)), np.array(hist_ep),
        np.array(hist_steps), np.array(hist_steps))
