"""Step-count and learning-rate schedules.

* ``inner_loop_lengths`` — the paper's geometric inner-loop growth
  ``K_s = ceil(beta^s * n0)`` (Algorithm 1 line 4).
* ``dspg_stepsize`` — the O(1/sqrt(k)) decaying step DSPG needs for
  convergence (the paper's baseline [11]).
* ``wsd`` — Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395) used by the
  minicpm-2b architecture config.
* plus constant / cosine / linear-warmup standards for the LM trainer.
"""

from __future__ import annotations

import math
from typing import Callable, Sequence

import jax.numpy as jnp

__all__ = [
    "inner_loop_lengths",
    "total_inner_steps",
    "dspg_stepsize",
    "constant",
    "cosine",
    "warmup_cosine",
    "wsd",
]


def inner_loop_lengths(beta: float, n0: int, num_outer: int) -> list[int]:
    """K_s = ceil(beta^s * n0) for s = 1..num_outer."""
    return [int(math.ceil((beta ** s) * n0)) for s in range(1, num_outer + 1)]


def total_inner_steps(beta: float, n0: int, num_outer: int) -> int:
    return sum(inner_loop_lengths(beta, n0, num_outer))


def dspg_stepsize(alpha0: float, decay: float = 0.5) -> Callable[[int], float]:
    """alpha_k = alpha0 / (k+1)^decay — the classic decaying step for
    decentralized stochastic proximal gradient (O(1/sqrt(T)) regime)."""
    def fn(k: int):
        return alpha0 / float((k + 1) ** decay)
    return fn


def constant(lr: float) -> Callable[[int], float]:
    return lambda step: lr


def cosine(lr: float, total_steps: int, final_frac: float = 0.1) -> Callable[[int], float]:
    def fn(step: int):
        t = min(step, total_steps) / max(total_steps, 1)
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + math.cos(math.pi * t)))
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable[[int], float]:
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step: int):
        if step < warmup:
            return lr * (step + 1) / warmup
        return cos(step - warmup)
    return fn


def wsd(lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.01) -> Callable[[int], float]:
    """Warmup-Stable-Decay: linear warmup, long constant plateau, short
    exponential-style decay tail (MiniCPM Sec. 4)."""
    def fn(step: int):
        if step < warmup:
            return lr * (step + 1) / warmup
        if step < warmup + stable:
            return lr
        t = min(step - warmup - stable, decay) / max(decay, 1)
        return lr * (final_frac ** t)
    return fn
