"""The unified `DecentralizedAlgorithm` protocol.

Every decentralized method in the repo — DPSVRG (paper Algorithm 1), DSPG
[paper ref. 11], DPG [ref. 10], GT-SVRG [refs 18/19], and the beyond-paper
loopless DPSVRG — is expressed as the same three pure transitions over an
algorithm-private state pytree with stacked node parameters (leading axis m):

    init()                        -> state        (all nodes at x0)
    step(state, batch, phi, a)    -> state        (one inner iteration)
    outer(state)                  -> state        (snapshot / full-grad refresh)
    end_outer(state, K)           -> state        (close an inner round, e.g.
                                                   Algorithm 1's tail average)

plus declarative :class:`AlgoMeta` (loop structure, gradient-evaluation cost
per step, gossip-rounds policy, step-size schedule, metric conventions).  The
single driver in :mod:`repro.core.runner` consumes this protocol and owns
everything the old bespoke ``*_run`` loops copy-pasted: batch sampling,
time-varying gossip scheduling, epoch/communication accounting, metric
recording, and an optional ``lax.scan`` fast path.

A new baseline is now a ~50-line factory returning an :class:`Algorithm`;
register it in :data:`ALGORITHMS` and it runs on every problem, schedule,
benchmark, and recorder in the repo.

The LM-scale trainer (``repro.train.steps``) shares the inner update via
:data:`UPDATE_RULES` + :func:`prox_gossip_update` instead of re-implementing
the SVRG correction a third time.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import compression, gossip, prox as prox_lib, schedules, svrg, \
    transport
from ..kernels.fused_update import ops as fused_ops

__all__ = [
    "Problem",
    "UpdateRule",
    "UPDATE_RULES",
    "prox_gossip_update",
    "AlgoMeta",
    "Algorithm",
    "ephemeral_steps",
    "DPSVRGHyperParams",
    "DSPGHyperParams",
    "build_node_grad_fn",
    "build_node_full_grad_fn",
    "build_dpsvrg_inner_step",
    "build_dspg_step",
    "build_gt_svrg_inner_step",
    "build_dvr_inner_step",
    "build_fused_svrg_inner",
    "build_fused_sgd_step",
    "dpsvrg_algorithm",
    "dspg_algorithm",
    "dpg_algorithm",
    "gt_svrg_algorithm",
    "loopless_dpsvrg_algorithm",
    "dvr_algorithm",
    "ALGORITHMS",
]


# ---------------------------------------------------------------------------
# Problem: what all algorithms run against
# ---------------------------------------------------------------------------

class Problem(NamedTuple):
    """A decentralized composite problem min F = (1/m) sum_i f_i + h.

    loss_fn:      ``loss_fn(params, batch) -> scalar`` per-node smooth loss
    prox:         the non-smooth regularizer's proximal operator
    x0:           stacked start point, leaves (m, ...)
    full_data:    per-node datasets, leaves (m, n, ...)
    objective_fn: optional override for the recorded objective F(x_bar)
    """
    loss_fn: Callable
    prox: prox_lib.Prox
    x0: Any
    full_data: Any
    objective_fn: Callable | None = None


# ---------------------------------------------------------------------------
# Hyper-parameters (canonical home; re-exported by core.dpsvrg for compat)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPSVRGHyperParams:
    alpha: float = 0.01          # constant step size (the VR payoff)
    beta: float = 1.07           # inner-loop growth base
    n0: int = 8                  # initial inner-loop length
    num_outer: int = 30          # S
    batch_size: int = 1          # paper uses single-sample inner steps
    k_max: int | None = None     # multi-consensus cap (None = faithful, k rounds at step k)
    single_consensus: bool = False  # Fig.3 ablation: one gossip round per step
    compress_bits: int | None = None  # int-quantized gossip w/ error feedback


@dataclasses.dataclass(frozen=True)
class DSPGHyperParams:
    alpha0: float = 0.01
    decay: float = 0.5           # alpha_k = alpha0 / (k+1)^decay
    batch_size: int = 1
    constant_step: bool = False  # with a constant step DSPG stalls (inexact convergence)


# ---------------------------------------------------------------------------
# Update rules: the loss-agnostic inner update shared with the LM trainer
# ---------------------------------------------------------------------------

class UpdateRule(NamedTuple):
    """Gradient-direction rule of the shared prox-gossip update.

    ``direction(g_now, g_snap, mu) -> v`` computes the descent direction from
    the minibatch gradient at the iterate, the minibatch gradient at the
    snapshot, and the snapshot full gradient.  Rules that don't need the
    snapshot (``needs_snapshot=False``) receive ``None`` for the latter two.
    """
    name: str
    needs_snapshot: bool
    direction: Callable


def _svrg_direction(g_now, g_snap, mu):
    return jax.tree.map(lambda a, b, c: a - b + c, g_now, g_snap, mu)


def _sgd_direction(g_now, g_snap, mu):
    return g_now


DPSVRG_RULE = UpdateRule("dpsvrg", True, _svrg_direction)
DSPG_RULE = UpdateRule("dspg", False, _sgd_direction)

UPDATE_RULES: dict[str, UpdateRule] = {
    "dpsvrg": DPSVRG_RULE,
    "dspg": DSPG_RULE,
}


def prox_gossip_update(params, v, phi, alpha, prox: prox_lib.Prox,
                       mix_fn: Callable = gossip.mix_stacked):
    """Algorithm 1 lines 8-11 for all nodes at once (shared hot path):

        q     = x - alpha * v
        q_hat = gossip(phi, q)
        x'    = prox_h^alpha(q_hat)

    The default ``mix_fn`` (``gossip.mix_stacked``) dispatches on the phi's
    wire format (dense / ``BandedPhi`` / ``PermutePhi``), so the same update
    serves every stateless transport backend; ``mix_fn`` stays pluggable for
    callers that need a bespoke collective.
    """
    q = jax.tree.map(lambda x, vi: x - alpha * vi.astype(x.dtype), params, v)
    q_hat = mix_fn(phi, q)
    return prox.apply(q_hat, alpha)


# ---------------------------------------------------------------------------
# Gradient function builders (stacked over nodes via vmap)
# ---------------------------------------------------------------------------

def build_node_grad_fn(loss_fn: Callable) -> Callable:
    """loss_fn(params, batch)->scalar  =>  grad over stacked params.

    Stacked signature: params leaves (m, ...), batch leaves (m, B, ...).
    vmap over the node axis keeps each node's gradient private, exactly as in
    decentralized learning — under GSPMD the vmapped axis is the node mesh
    axis, so no cross-node communication happens here.
    """
    g = jax.grad(loss_fn)
    return jax.vmap(g)


def build_node_full_grad_fn(loss_fn: Callable, full_batch) -> Callable:
    """Full local gradient closure over each node's entire dataset."""
    g = jax.vmap(jax.grad(loss_fn))

    def full_grad(params):
        return g(params, full_batch)

    return full_grad


# ---------------------------------------------------------------------------
# Jitted step builders
# ---------------------------------------------------------------------------

# Step functions are memoized on their (hashable) ingredients so that
# REBUILDING an Algorithm — as every sweep point does — returns the SAME
# function objects, and therefore the same jax.jit compilation caches and the
# same runner chunk executors.  This is what lets compiled scan/resident
# chunks survive across ``runner.run`` calls: the executable cache in
# ``core.runner`` keys on step identity, and step identity is stable across
# instances with identical loss/prox closures.  Entries hold no datasets
# (data-bound steps like DPG's full-gradient step are deliberately NOT
# memoized), so the LRU cap only bounds compiled-code retention.
_SHARED_STEPS: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()
_SHARED_STEPS_MAX = 128

# When True, _shared_step builds fresh functions WITHOUT touching the global
# LRU.  The batched sweep executor rebuilds algorithms INSIDE a trace (cell
# hyperparameters arrive as tracers, e.g. a vmapped lambda grid), and those
# tracer-closing steps must never be cached: their keys embed fresh Prox
# objects so they could never be served again, but they would still evict
# legitimate entries and pin tracers past their trace.
_EPHEMERAL_STEPS = False


@contextlib.contextmanager
def ephemeral_steps():
    """Build algorithm steps without memoizing them (in-trace rebuilds)."""
    global _EPHEMERAL_STEPS
    prev = _EPHEMERAL_STEPS
    _EPHEMERAL_STEPS = True
    try:
        yield
    finally:
        _EPHEMERAL_STEPS = prev


def memoize_into(cache: "collections.OrderedDict", cap: int, key: tuple,
                 make: Callable[[], Callable]) -> Callable:
    """Bounded (LRU) build-on-miss memoizer — shared by the step cache here
    and the runner's executable cache."""
    fn = cache.get(key)
    if fn is None:
        fn = make()
        cache[key] = fn
        while len(cache) > cap:
            cache.popitem(last=False)
    else:
        cache.move_to_end(key)
    return fn


def _shared_step(key: tuple, make: Callable[[], Callable]) -> Callable:
    if _EPHEMERAL_STEPS:
        return make()
    return memoize_into(_SHARED_STEPS, _SHARED_STEPS_MAX, key, make)


def build_dpsvrg_inner_step(loss_fn: Callable, prox: prox_lib.Prox,
                            compress_bits: int | None = None):
    """Returns jitted ``step(params, svrg_state, batch, phi, alpha, cstate)
    -> (params, cstate)`` implementing Algorithm 1 lines 7-11 for all nodes
    at once.  ``phi`` may be any transport wire format (dense, ``BandedPhi``,
    ``PermutePhi``, ``CompressedPhi``) — the mix dispatches on its type at
    trace time.  ``cstate`` is the error-feedback state for compressed
    gossip (None, returned untouched, for stateless transports).  The legacy
    ``compress_bits`` hyperparameter wraps the incoming phi in a
    ``CompressedPhi`` so hp-level compression and the ``compressed``
    transport backend share one code path.
    """
    def make():
        node_grad = build_node_grad_fn(loss_fn)

        @jax.jit
        def step(params, svrg_state, batch, phi, alpha, cstate):
            if compress_bits is not None and \
                    not isinstance(phi, compression.CompressedPhi):
                phi = compression.CompressedPhi(phi, compress_bits)
            v = svrg.corrected_gradient(node_grad, params, svrg_state, batch)
            q = jax.tree.map(lambda x, vi: x - alpha * vi.astype(x.dtype),
                             params, v)
            q_hat, cstate = compression.mix_with_state(phi, q, cstate)
            x = prox.apply(q_hat, alpha)
            return x, cstate

        return step

    return _shared_step(("dpsvrg_inner", loss_fn, prox, compress_bits), make)


def build_dspg_step(loss_fn: Callable, prox: prox_lib.Prox):
    """DSPG [paper ref. 11]: plain stochastic gradient + single gossip + prox,
    decaying step size."""
    def make():
        node_grad = build_node_grad_fn(loss_fn)

        @jax.jit
        def step(params, batch, w, alpha):
            g = node_grad(params, batch)
            return prox_gossip_update(params, g, w, alpha, prox)

        return step

    return _shared_step(("dspg_step", loss_fn, prox), make)


def build_gt_svrg_inner_step(loss_fn: Callable, prox: prox_lib.Prox):
    """GT-SVRG inner update: prox-gossip step + gradient-tracking recursion.

    Both collectives (the iterate mix and the tracker mix) route through
    ``compression.mix_with_state``, so the step can ride the stateful
    ``compressed`` transport: ``cstate`` is a pair of error-feedback states
    (one per transmitted quantity — iterate and tracker carry independent
    quantization residuals), or ``None`` for stateless transports.
    """
    def make():
        node_grad = build_node_grad_fn(loss_fn)

        @jax.jit
        def inner(params, tracker, v_prev, est, batch, w, a, cstate):
            cq, ct = cstate if cstate is not None else (None, None)
            q = jax.tree.map(lambda x, y: x - a * y, params, tracker)
            q_hat, cq = compression.mix_with_state(w, q, cq)
            new_params = prox.apply(q_hat, a)
            v_new = svrg.corrected_gradient(node_grad, new_params, est, batch)
            t_mixed, ct = compression.mix_with_state(w, tracker, ct)
            new_tracker = jax.tree.map(
                lambda ty, vn, vp: ty + vn - vp, t_mixed, v_new, v_prev)
            new_cstate = None if cq is None and ct is None else (cq, ct)
            return new_params, new_tracker, v_new, new_cstate

        return inner

    return _shared_step(("gt_svrg_inner", loss_fn, prox), make)


def build_dvr_inner_step(loss_fn: Callable, prox: prox_lib.Prox, rho: float):
    """Dual-Free DVR inner update (Hendrikx et al., arXiv 2006.14384),
    adapted to this runner's primal sampled-batch interface.

    Exact DVR runs dual-free coordinate ascent with a PER-SAMPLE dual table
    z_ij and needs the sampled indices j to update it; the runner's sampling
    contract hands steps batch VALUES only.  What this plugin keeps is DVR's
    structure that the paper's multi-consensus lacks: variance-reduced local
    computation DECOUPLED from a partial communication step with its own
    step size ``rho`` (DVR's p_comm-scaled gossip) —

        v  = SVRG-corrected gradient          (dual-free VR surrogate)
        y  = x - alpha v                      (local computation step)
        x' = prox_h((1-rho) y + rho W y)      (damped gossip: rho = 1 is the
                                               usual full mixing, rho < 1
                                               trades consensus for staleness
                                               tolerance)

    The mix routes through ``compression.mix_with_state`` so DVR rides
    stateful transports (compressed / scenario) like the DPSVRG family.
    """
    def make():
        node_grad = build_node_grad_fn(loss_fn)

        @jax.jit
        def step(params, est, batch, phi, alpha, cstate):
            v = svrg.corrected_gradient(node_grad, params, est, batch)
            y = jax.tree.map(lambda x, vi: x - alpha * vi.astype(x.dtype),
                             params, v)
            y_mixed, cstate = compression.mix_with_state(phi, y, cstate)
            q = jax.tree.map(lambda a, b: (1.0 - rho) * a + rho * b,
                             y, y_mixed)
            return prox.apply(q, alpha), cstate

        return step

    return _shared_step(("dvr_inner", loss_fn, prox, rho), make)


# ---------------------------------------------------------------------------
# Fused resident-step twins (kernels.fused_update)
# ---------------------------------------------------------------------------
#
# ``runner.run(kernel="pallas"|"auto")`` swaps these into the compiled chunk
# body in place of the unfused steps.  They compute the SAME update —
# prox(W @ (x - alpha*v)) — through one fused kernel pass over the stacked
# (m, d) buffer instead of a chain of separate XLA ops, and fall back to the
# unfused step AT TRACE TIME whenever the configuration has no fused
# lowering:
#
# * the phi wire format has no static dense matrix (``transport.mix_matrix``
#   returns None: ppermute mesh collectives, compressed/scenario wrappers),
# * a stateful transport threads a mix state (cstate is not None),
# * the prox has no ``fused_spec`` (only l1 / sql2 / none lower),
# * mode="auto" at small per-node d, where the unfused XLA body wins
#   (``fused_ops.FUSED_MIN_D``).
#
# All checks are Python-level on static structure, so the fallback costs
# nothing in the compiled program.

def _fused_fallback(mode: str, prox: prox_lib.Prox, phi, cstate, params):
    """-> (dense W or None, fused spec or None); (None, None) = use the
    unfused step."""
    spec = prox.fused_spec
    if spec is None or cstate is not None:
        return None, None
    if mode == "auto" and not fused_ops.fused_wins(
            fused_ops.tree_node_dim(params)):
        return None, None
    w = transport.mix_matrix(phi)
    if w is None:
        return None, None
    return w, spec


def build_fused_svrg_inner(loss_fn: Callable, prox: prox_lib.Prox, mode: str,
                           rho: float | None = None):
    """Fused twin of ``build_dpsvrg_inner_step`` (rho=None) /
    ``build_dvr_inner_step`` (rho set: W_eff = (1-rho) I + rho W folds DVR's
    damped gossip into the kernel's mix matrix).  Same signature:
    ``inner(params, est, batch, phi, alpha, cstate) -> (params, cstate)``.
    """
    base = (build_dvr_inner_step(loss_fn, prox, rho) if rho is not None
            else build_dpsvrg_inner_step(loss_fn, prox))

    def make():
        node_grad = build_node_grad_fn(loss_fn)

        def inner(params, est, batch, phi, alpha, cstate):
            w, spec = _fused_fallback(mode, prox, phi, cstate, params)
            if w is None:
                return base(params, est, batch, phi, alpha, cstate)
            if rho is not None:
                w = (1.0 - rho) * jnp.eye(w.shape[0], dtype=w.dtype) + rho * w
            kind, lam = spec
            g_now = node_grad(params, batch)
            g_snap = node_grad(est.snapshot, batch)
            x = fused_ops.fused_resident_step(
                w, params, (g_now, g_snap, est.full_grad), alpha, lam,
                rule="svrg", prox_kind=kind)
            return x, cstate

        return inner

    return _shared_step(("fused_svrg_inner", loss_fn, prox, mode, rho), make)


def build_fused_sgd_step(loss_fn: Callable, prox: prox_lib.Prox, mode: str):
    """Fused twin of ``build_dspg_step``: one kernel pass for
    prox(W @ (x - alpha*g))."""
    base = build_dspg_step(loss_fn, prox)

    def make():
        node_grad = build_node_grad_fn(loss_fn)

        def step_fn(params, batch, phi, alpha):
            w, spec = _fused_fallback(mode, prox, phi, None, params)
            if w is None:
                return base(params, batch, phi, alpha)
            kind, lam = spec
            g = node_grad(params, batch)
            return fused_ops.fused_resident_step(
                w, params, (g,), alpha, lam, rule="sgd", prox_kind=kind)

        return step_fn

    return _shared_step(("fused_sgd_step", loss_fn, prox, mode), make)


# ---------------------------------------------------------------------------
# Protocol: declarative metadata + the state/step/outer triple
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AlgoMeta:
    """Everything the generic runner needs to know about a method, declared
    up front instead of encoded in a bespoke loop.

    Loop structure — exactly one of:
      outer_lengths: inner-round lengths (K_1, ..., K_S); the runner calls
                     ``outer()`` before each round and ``end_outer()`` after
      num_steps:     flat loop length (``outer()`` only on coin flips)

    Cost accounting (effective-epoch metric, per inner step):
      step_grad_factor: single-sample grad evals per node per batch element
                        (2 for SVRG-corrected steps, 1 for plain SGD)
      outer_full_grad:  charge m*n evals at each ``outer()`` refresh
      init_full_grad:   charge m*n evals at ``init()`` (loopless warm start)

    Gossip policy:
      gossip_rounds(k): consensus rounds at inner step k (in-round k for
                        outer/inner methods, global t for flat ones); the
                        runner turns rounds into one pre-multiplied Phi
      gossip_payloads:  distinct quantities transmitted per mixing (wire
                        accounting multiplier): 1 for prox-gossip methods,
                        2 for gradient tracking, which gossips the iterate
                        AND the tracking direction with the same Phi
      slot_start:       first slot of the time-varying schedule consumed

    Recording conventions (kept method-by-method identical to the historical
    loops so downstream figure scripts are unaffected):
      stepsize(t):      step size at global step t (1-based)
      snapshot_prob:    loopless coin-flip probability (flat loops only)
      track_consensus:  record mean ||x_i - x_bar|| (else zeros)
      comm_metric:      "gossip" (cumulative rounds) | "steps"
      epoch_metric:     "grad" (evals / (m n)) | "steps" (DPG: 1 epoch/step)
      record_key:       "round" | "global" — which counter record_every keys on
      final_record:     force a terminal record (deduplicated by the runner)

    Wire format:
      compress_bits:    the method itself quantizes its gossip payload at
                        this int width (error feedback threaded through the
                        algorithm state).  The runner wraps the resolved
                        transport in a CompressedBackend at these bits so
                        the wire-byte accounting matches what actually moves
                        (and raises if a conflicting compressed transport is
                        requested).

    Resident-mode metric contract (``runner.run(resident=True)``):
      resident_objective: traceable ``objective(stacked_params, full_data)
                        -> scalar`` evaluated INSIDE the jitted on-device
                        record kernel.  None (the default) means the
                        standard composite objective F(x̄) = mean_i
                        f_i(x̄) + h(x̄) via the vmap'd loss + prox value —
                        correct for every method in the repo.  Algorithms
                        whose recorded objective differs from F(x̄) declare
                        it here; the consensus column always comes from the
                        in-graph ``jnp`` norms when ``track_consensus`` is
                        set.  (``Problem.objective_fn`` still overrides on
                        the host paths, and is used by the resident path
                        too when set — but then it must be jax-traceable.)

    Fused-kernel eligibility (``runner.run(kernel="pallas"|"auto")``):
      fused_step:       ``fused_step(mode) -> step`` returning a step with
                        the standard ``(state, batch, phi, alpha) -> state``
                        signature whose inner update runs through the fused
                        resident-step kernel (``kernels.fused_update``) when
                        the traced configuration lowers, falling back to the
                        unfused step otherwise (see the fused-twin builders
                        above).  ``mode`` is "pallas" (fuse whenever a
                        lowering exists) or "auto" (additionally require the
                        shape to be in the kernel's winning regime).  None —
                        the default — declares the method has no fused
                        lowering (e.g. gradient tracking's two-payload step)
                        and the runner silently keeps the unfused body.
    """
    name: str
    stepsize: Callable[[int], float]
    outer_lengths: tuple[int, ...] | None = None
    num_steps: int | None = None
    batch_size: int = 1
    step_grad_factor: int = 1
    outer_full_grad: bool = False
    init_full_grad: bool = False
    gossip_rounds: Callable[[int], int] = lambda k: 1
    gossip_payloads: int = 1
    slot_start: int = 0
    snapshot_prob: float | None = None
    track_consensus: bool = False
    comm_metric: str = "steps"
    epoch_metric: str = "grad"
    record_key: str = "round"
    final_record: bool = True
    compress_bits: int | None = None
    resident_objective: Callable | None = None
    fused_step: Callable[[str], Callable] | None = None


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A decentralized algorithm bound to a :class:`Problem`.

    ``step`` must be jit-compatible (the runner traces it under ``lax.scan``
    on the fast path); ``init``/``outer``/``end_outer`` run on host between
    dispatches and may mix eager and jitted work.

    ``init_mix_state`` opts the algorithm into STATEFUL gossip transports
    (the ``compressed`` backend's error-feedback residual): it injects a
    fresh mix state into an initialized algorithm state, and the step must
    thread that state through its mix (``compression.mix_with_state``).
    DPSVRG, GT-SVRG, and loopless DPSVRG all do (GT-SVRG carries one
    residual per transmitted quantity — iterate and tracker); algorithms
    leaving it None can only be driven by stateless transports.

    The TRACEABLE outer-transition contract (``outer_traced`` /
    ``end_outer_traced`` / ``device_state``) lets the runner fold the
    outer-round transitions into the compiled chunk program (``lax.cond``
    on a precomputed round schedule) instead of dispatching ``outer`` /
    ``end_outer`` from host between chunks — required for batched sweeps
    (``core.sweep``) and the default for ``runner.run(resident=True)``
    when declared:

    * ``outer_traced(state, full_data) -> state`` — same transition as
      ``outer`` but jit/vmap-safe with the dataset passed EXPLICITLY (so
      the compiled chunk reads the staged device-resident copy instead of
      baking the closed-over host array in as a constant) and a FIXED
      output pytree structure.
    * ``end_outer_traced(state, k) -> state`` — same as ``end_outer`` with
      the round length as a traced f32 scalar.
    * ``device_state(state) -> state`` — one-time host-side shim that gives
      the initial state the fixed structure the traced transitions need
      (e.g. DPSVRG's ``est=None`` becomes a zero-filled ``SvrgState``
      placeholder; it is overwritten by the first in-chunk ``outer`` before
      any step reads it).  None means the init state already has it.
    """
    meta: AlgoMeta
    init: Callable[[], Any]
    step: Callable[[Any, Any, Any, Any], Any]   # (state, batch, phi, alpha)
    outer: Callable[[Any], Any] | None = None
    end_outer: Callable[[Any, int], Any] | None = None
    rule: UpdateRule | None = None
    init_mix_state: Callable[[Any], Any] | None = None
    outer_traced: Callable[[Any, Any], Any] | None = None
    end_outer_traced: Callable[[Any, Any], Any] | None = None
    device_state: Callable[[Any], Any] | None = None

    @staticmethod
    def get_params(state):
        return state.params


# Algorithm-private states.  All carry stacked params; the rest is method
# bookkeeping that rides through ``lax.scan`` as part of the carry.

class ParamState(NamedTuple):
    params: Any


class DPSVRGState(NamedTuple):
    params: Any
    anchor: Any                       # snapshot point for the NEXT refresh
    est: svrg.SvrgState | None        # current snapshot + full gradient
    inner_sum: Any                    # tail-average accumulator (line 13)
    cstate: Any                       # compression error-feedback state


class GTSVRGState(NamedTuple):
    params: Any
    anchor: Any
    est: svrg.SvrgState | None
    tracker: Any                      # gradient-tracking direction y_i
    v_prev: Any
    inner_sum: Any
    cstate: Any = None                # (iterate, tracker) error-feedback pair


class LooplessState(NamedTuple):
    params: Any
    est: svrg.SvrgState
    cstate: Any = None                # compression error-feedback state


def _zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


# Traced outer transitions are memoized like the steps: rebuilt Algorithm
# instances with identical loss closures return the SAME function objects,
# so the runner's chunk executors (whose cache keys embed these identities)
# stay warm across sweep points.  They close over NO data — the dataset is
# an explicit argument, read from the staged device-resident copy.

def _svrg_outer_traced(loss_fn: Callable) -> Callable:
    """snapshot <- anchor, full_grad <- grad at anchor over the full data,
    inner_sum <- 0 — the traced twin of the DPSVRG/GT-SVRG ``outer``."""
    def make():
        node_grad = build_node_grad_fn(loss_fn)

        def outer_traced(state, full_data):
            est = svrg.SvrgState(snapshot=state.anchor,
                                 full_grad=node_grad(state.anchor, full_data))
            return state._replace(est=est,
                                  inner_sum=_zeros_like(state.params))

        return outer_traced

    return _shared_step(("svrg_outer_traced", loss_fn), make)


def _tail_average_end_outer_traced() -> Callable:
    """anchor <- inner_sum / K (Algorithm 1 line 13) with K a traced f32."""
    def make():
        def end_outer_traced(state, k):
            return state._replace(
                anchor=jax.tree.map(lambda acc: acc / k, state.inner_sum))

        return end_outer_traced

    return _shared_step(("tail_average_end_outer",), make)


def _loopless_outer_traced(loss_fn: Callable) -> Callable:
    """Coin-flip snapshot refresh at the CURRENT iterate (L-SVRG style)."""
    def make():
        node_grad = build_node_grad_fn(loss_fn)

        def outer_traced(state, full_data):
            return state._replace(est=svrg.SvrgState(
                snapshot=state.params,
                full_grad=node_grad(state.params, full_data)))

        return outer_traced

    return _shared_step(("loopless_outer_traced", loss_fn), make)


def _svrg_placeholder_state(state):
    """Fixed-structure device state: fill ``est=None`` with a zero
    ``SvrgState`` placeholder (overwritten by the first in-chunk ``outer``
    before any step reads it)."""
    if state.est is not None:
        return state
    est = svrg.SvrgState(snapshot=state.anchor,
                         full_grad=_zeros_like(state.params))
    return state._replace(est=est)


# ---------------------------------------------------------------------------
# Factories: one per method, each a ~40-line plugin
# ---------------------------------------------------------------------------

def dpsvrg_algorithm(problem: Problem, hp: DPSVRGHyperParams) -> Algorithm:
    """Paper Algorithm 1: SVRG-corrected prox step + multi-consensus gossip,
    growing inner rounds K_s = ceil(beta^s n0), tail-average snapshots."""
    inner = build_dpsvrg_inner_step(problem.loss_fn, problem.prox,
                                    compress_bits=hp.compress_bits)
    full_grad_fn = build_node_full_grad_fn(problem.loss_fn, problem.full_data)

    def init():
        cstate = (compression.init_state(problem.x0)
                  if hp.compress_bits is not None else None)
        return DPSVRGState(params=problem.x0, anchor=problem.x0, est=None,
                           inner_sum=_zeros_like(problem.x0), cstate=cstate)

    def init_mix_state(state, make=compression.init_state):
        # the stateful transport threads its state through cstate; ``make``
        # defaults to the compressed backend's error-feedback residual, and
        # the runner passes the resolved backend's own initializer (bound to
        # its aux) for other stateful transports (scenario delay buffers)
        return state._replace(cstate=make(problem.x0))

    def outer(state):
        est = svrg.SvrgState(snapshot=state.anchor,
                             full_grad=full_grad_fn(state.anchor))
        return state._replace(est=est, inner_sum=_zeros_like(state.params))

    def make_step():
        def step(state, batch, phi, alpha):
            params, cstate = inner(state.params, state.est, batch, phi,
                                   alpha, state.cstate)
            return state._replace(
                params=params, cstate=cstate,
                inner_sum=svrg.tree_add(state.inner_sum, params))
        return step

    step = _shared_step(("dpsvrg_proto_step", inner), make_step)

    def fused_step(mode):
        finner = build_fused_svrg_inner(problem.loss_fn, problem.prox, mode)

        def make_fused():
            def fstep(state, batch, phi, alpha):
                params, cstate = finner(state.params, state.est, batch, phi,
                                        alpha, state.cstate)
                return state._replace(
                    params=params, cstate=cstate,
                    inner_sum=svrg.tree_add(state.inner_sum, params))
            return fstep

        return _shared_step(("dpsvrg_proto_fused", finner), make_fused)

    def end_outer(state, K):
        return state._replace(
            anchor=jax.tree.map(lambda acc: acc / K, state.inner_sum))

    if hp.single_consensus:
        rounds = lambda k: 1
    elif hp.k_max is None:
        rounds = lambda k: k
    else:
        rounds = lambda k: min(k, hp.k_max)

    meta = AlgoMeta(
        name="dpsvrg",
        stepsize=schedules.constant(hp.alpha),
        outer_lengths=tuple(
            schedules.inner_loop_lengths(hp.beta, hp.n0, hp.num_outer)),
        batch_size=hp.batch_size,
        step_grad_factor=2,
        outer_full_grad=True,
        gossip_rounds=rounds,
        track_consensus=True,
        comm_metric="gossip",
        record_key="round",
        final_record=True,
        compress_bits=hp.compress_bits,
        # hp-level quantization threads error feedback through every mix —
        # no fused lowering exists for that configuration
        fused_step=None if hp.compress_bits is not None else fused_step,
    )
    return Algorithm(meta=meta, init=init, step=step, outer=outer,
                     end_outer=end_outer, rule=DPSVRG_RULE,
                     init_mix_state=init_mix_state,
                     outer_traced=_svrg_outer_traced(problem.loss_fn),
                     end_outer_traced=_tail_average_end_outer_traced(),
                     device_state=_svrg_placeholder_state)


def dspg_algorithm(problem: Problem, hp: DSPGHyperParams,
                   num_steps: int) -> Algorithm:
    """DSPG baseline: one stochastic prox-gradient + one gossip per step."""
    step_fn = build_dspg_step(problem.loss_fn, problem.prox)

    def make_step():
        def step(state, batch, phi, alpha):
            return ParamState(step_fn(state.params, batch, phi, alpha))
        return step

    step = _shared_step(("dspg_proto_step", step_fn), make_step)

    def fused_step(mode):
        fstep_fn = build_fused_sgd_step(problem.loss_fn, problem.prox, mode)

        def make_fused():
            def fstep(state, batch, phi, alpha):
                return ParamState(fstep_fn(state.params, batch, phi, alpha))
            return fstep

        return _shared_step(("dspg_proto_fused", fstep_fn), make_fused)

    meta = AlgoMeta(
        name="dspg",
        stepsize=(schedules.constant(hp.alpha0) if hp.constant_step
                  else schedules.dspg_stepsize(hp.alpha0, hp.decay)),
        num_steps=num_steps,
        batch_size=hp.batch_size,
        step_grad_factor=1,
        slot_start=1,
        track_consensus=True,
        fused_step=fused_step,
    )
    return Algorithm(meta=meta, init=lambda: ParamState(problem.x0),
                     step=step, rule=DSPG_RULE)


def dpg_algorithm(problem: Problem, alpha: float, num_steps: int) -> Algorithm:
    """DPG [paper ref. 10]: deterministic full local gradients, one gossip +
    prox per step.  The smooth anchor: one effective epoch per step."""
    full_grad_fn = build_node_full_grad_fn(problem.loss_fn, problem.full_data)
    prox = problem.prox

    @jax.jit
    def _step(params, w, a):
        g = full_grad_fn(params)
        q = jax.tree.map(lambda x, gi: x - a * gi, params, g)
        q_hat = gossip.mix_stacked(w, q)
        return prox.apply(q_hat, a)

    def step(state, batch, phi, alpha):
        return ParamState(_step(state.params, phi, alpha))

    def fused_step(mode):
        # keyed on ``_step`` (unique per algorithm instance, so per dataset):
        # repeated runner.run calls must get the SAME fstep object back or
        # the resident-exec cache misses and every run retraces+recompiles
        # the chunk executor — at LM-scale d that recompile dwarfs the run
        def make_fused():
            def fstep(state, batch, phi, alpha):
                w, spec = _fused_fallback(mode, prox, phi, None,
                                          state.params)
                if w is None:
                    return ParamState(_step(state.params, phi, alpha))
                kind, lam = spec
                g = full_grad_fn(state.params)
                return ParamState(fused_ops.fused_resident_step(
                    w, state.params, (g,), alpha, lam, rule="sgd",
                    prox_kind=kind))
            return fstep

        return _shared_step(("dpg_proto_fused", _step, mode), make_fused)

    meta = AlgoMeta(
        name="dpg",
        stepsize=schedules.constant(alpha),
        num_steps=num_steps,
        batch_size=0,
        step_grad_factor=0,
        slot_start=1,
        epoch_metric="steps",
        fused_step=fused_step,
    )
    return Algorithm(meta=meta, init=lambda: ParamState(problem.x0),
                     step=step)


def gt_svrg_algorithm(problem: Problem, alpha: float, num_outer: int,
                      inner_steps: int, batch_size: int = 1) -> Algorithm:
    """GT-SVRG [paper refs 18/19]: SVRG estimator + gradient tracking; one
    gossip round per step (tracking replaces multi-consensus)."""
    inner = build_gt_svrg_inner_step(problem.loss_fn, problem.prox)
    full_grad_fn = build_node_full_grad_fn(problem.loss_fn, problem.full_data)

    def init():
        # standard GT init: tracker starts at the x0 full gradient (computed
        # once here, re-charged per outer round exactly like the host loops)
        est = svrg.SvrgState(snapshot=problem.x0,
                             full_grad=full_grad_fn(problem.x0))
        return GTSVRGState(params=problem.x0, anchor=problem.x0, est=est,
                           tracker=est.full_grad, v_prev=est.full_grad,
                           inner_sum=_zeros_like(problem.x0))

    def init_mix_state(state, make=compression.init_state):
        # one transport state per transmitted quantity: the step gossips
        # both the iterate and the tracking direction
        return state._replace(cstate=(make(problem.x0), make(problem.x0)))

    def outer(state):
        est = svrg.SvrgState(snapshot=state.anchor,
                             full_grad=full_grad_fn(state.anchor))
        return state._replace(est=est, inner_sum=_zeros_like(state.params))

    def make_step():
        def step(state, batch, phi, alpha):
            params, tracker, v_prev, cstate = inner(
                state.params, state.tracker, state.v_prev, state.est, batch,
                phi, alpha, state.cstate)
            return state._replace(
                params=params, tracker=tracker, v_prev=v_prev, cstate=cstate,
                inner_sum=svrg.tree_add(state.inner_sum, params))
        return step

    step = _shared_step(("gt_svrg_proto_step", inner), make_step)

    def end_outer(state, K):
        return state._replace(
            anchor=jax.tree.map(lambda acc: acc / K, state.inner_sum))

    meta = AlgoMeta(
        name="gt_svrg",
        stepsize=schedules.constant(alpha),
        outer_lengths=(inner_steps,) * num_outer,
        batch_size=batch_size,
        step_grad_factor=2,
        outer_full_grad=True,
        gossip_payloads=2,   # the step mixes the iterate AND the tracker
        record_key="global",
        final_record=False,
    )
    return Algorithm(meta=meta, init=init, step=step, outer=outer,
                     end_outer=end_outer, rule=DPSVRG_RULE,
                     init_mix_state=init_mix_state,
                     outer_traced=_svrg_outer_traced(problem.loss_fn),
                     end_outer_traced=_tail_average_end_outer_traced())


def loopless_dpsvrg_algorithm(problem: Problem, alpha: float, num_steps: int,
                              snapshot_prob: float = 0.05,
                              consensus_rounds: int = 2,
                              batch_size: int = 1) -> Algorithm:
    """BEYOND-PAPER: L-SVRG-style coin-flip snapshots — fixed-shape steps,
    no outer-loop bookkeeping (the variant the LM trainer approximates)."""
    inner = build_dpsvrg_inner_step(problem.loss_fn, problem.prox)
    full_grad_fn = build_node_full_grad_fn(problem.loss_fn, problem.full_data)

    def init():
        est = svrg.SvrgState(snapshot=problem.x0,
                             full_grad=full_grad_fn(problem.x0))
        return LooplessState(params=problem.x0, est=est)

    def init_mix_state(state, make=compression.init_state):
        return state._replace(cstate=make(problem.x0))

    def outer(state):
        return state._replace(est=svrg.SvrgState(
            snapshot=state.params, full_grad=full_grad_fn(state.params)))

    def make_step():
        def step(state, batch, phi, alpha):
            params, cstate = inner(state.params, state.est, batch, phi,
                                   alpha, state.cstate)
            return state._replace(params=params, cstate=cstate)
        return step

    step = _shared_step(("loopless_proto_step", inner), make_step)

    def fused_step(mode):
        finner = build_fused_svrg_inner(problem.loss_fn, problem.prox, mode)

        def make_fused():
            def fstep(state, batch, phi, alpha):
                params, cstate = finner(state.params, state.est, batch, phi,
                                        alpha, state.cstate)
                return state._replace(params=params, cstate=cstate)
            return fstep

        return _shared_step(("loopless_proto_fused", finner), make_fused)

    meta = AlgoMeta(
        name="loopless_dpsvrg",
        stepsize=schedules.constant(alpha),
        num_steps=num_steps,
        batch_size=batch_size,
        step_grad_factor=2,
        outer_full_grad=True,
        init_full_grad=True,
        gossip_rounds=lambda t: consensus_rounds,
        snapshot_prob=snapshot_prob,
        fused_step=fused_step,
    )
    return Algorithm(meta=meta, init=init, step=step, outer=outer,
                     rule=DPSVRG_RULE, init_mix_state=init_mix_state,
                     outer_traced=_loopless_outer_traced(problem.loss_fn))


def dvr_algorithm(problem: Problem, alpha: float, num_steps: int,
                  rho: float = 0.5, snapshot_prob: float = 0.05,
                  batch_size: int = 1) -> Algorithm:
    """Dual-Free DVR (Hendrikx et al., arXiv 2006.14384) — see
    :func:`build_dvr_inner_step` for the adaptation notes.  Flat loop with
    loopless coin-flip snapshot refreshes (DVR samples its full-gradient
    resyncs the same way); one gossip round per step with communication step
    size ``rho`` — the scenario matrix's non-gradient-tracking VR column."""
    inner = build_dvr_inner_step(problem.loss_fn, problem.prox, rho)
    full_grad_fn = build_node_full_grad_fn(problem.loss_fn, problem.full_data)

    def init():
        est = svrg.SvrgState(snapshot=problem.x0,
                             full_grad=full_grad_fn(problem.x0))
        return LooplessState(params=problem.x0, est=est)

    def init_mix_state(state, make=compression.init_state):
        return state._replace(cstate=make(problem.x0))

    def outer(state):
        return state._replace(est=svrg.SvrgState(
            snapshot=state.params, full_grad=full_grad_fn(state.params)))

    def make_step():
        def step(state, batch, phi, alpha):
            params, cstate = inner(state.params, state.est, batch, phi,
                                   alpha, state.cstate)
            return state._replace(params=params, cstate=cstate)
        return step

    step = _shared_step(("dvr_proto_step", inner), make_step)

    def fused_step(mode):
        finner = build_fused_svrg_inner(problem.loss_fn, problem.prox, mode,
                                        rho=rho)

        def make_fused():
            def fstep(state, batch, phi, alpha):
                params, cstate = finner(state.params, state.est, batch, phi,
                                        alpha, state.cstate)
                return state._replace(params=params, cstate=cstate)
            return fstep

        return _shared_step(("dvr_proto_fused", finner), make_fused)

    meta = AlgoMeta(
        name="dvr",
        stepsize=schedules.constant(alpha),
        num_steps=num_steps,
        batch_size=batch_size,
        step_grad_factor=2,
        outer_full_grad=True,
        init_full_grad=True,
        snapshot_prob=snapshot_prob,
        fused_step=fused_step,
    )
    return Algorithm(meta=meta, init=init, step=step, outer=outer,
                     rule=DPSVRG_RULE, init_mix_state=init_mix_state,
                     outer_traced=_loopless_outer_traced(problem.loss_fn))


ALGORITHMS: dict[str, Callable[..., Algorithm]] = {
    "dpsvrg": dpsvrg_algorithm,
    "dspg": dspg_algorithm,
    "dpg": dpg_algorithm,
    "gt_svrg": gt_svrg_algorithm,
    "loopless_dpsvrg": loopless_dpsvrg_algorithm,
    "dvr": dvr_algorithm,
}
