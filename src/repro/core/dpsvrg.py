"""DPSVRG (paper Algorithm 1) public surface + the centralized reference.

The algorithms themselves live behind the unified protocol in
``repro.core.algorithm`` (state/step/outer + declarative metadata) and are
driven by the single generic ``repro.core.runner.run`` loop, which owns batch
sampling, time-varying gossip scheduling, metric recording, the optional
``lax.scan`` fast path, and dense/banded gossip dispatch.  This module keeps
the canonical names stable:

* ``DPSVRGHyperParams`` / ``DSPGHyperParams`` — canonical home is
  ``core.algorithm``; re-exported here.
* ``build_dpsvrg_inner_step`` / ``build_dspg_step`` / ``build_node_grad_fn``
  / ``build_node_full_grad_fn`` — re-exported step builders (also used by
  ``core.inexact``, the kernels' reference paths, and the frozen
  pre-refactor oracle in ``tests/_legacy_runs.py``).
* ``centralized_prox_gd`` — the full-batch proximal-gradient reference used
  to estimate F(x*) for the optimality-gap metric.

The historical ``dpsvrg_run`` / ``dspg_run`` wrappers are GONE: build an
``Algorithm`` via ``algorithm.ALGORITHMS`` and call ``runner.run`` —

    problem = algorithm.Problem(loss_fn, prox, x0_stacked, full_data)
    algo = algorithm.ALGORITHMS["dpsvrg"](problem, DPSVRGHyperParams(...))
    res = runner.run(algo, problem, schedule, ExecSpec(scan=True),
                     record_every=...)
    res.params, res.history

— and hyperparameter GRIDS (λ, seeds, topologies) batch into one staged
device program via ``runner.run_sweep`` (``core.sweep``): DPSVRG declares
the traceable outer-transition contract (``Algorithm.outer_traced`` /
``end_outer_traced``), so its growing K_s rounds execute entirely inside
the compiled chunks.

Algorithm 1 (per node i, inner step k of outer round s):
    v_i   = grad_B f_i(x_i) - grad_B f_i(x~_i) + full_grad_i(x~_i)
    q_i   = x_i - alpha * v_i
    q^_i  = sum_j Phi^(k,s)_{ij} q_j          (multi-consensus: k gossip rounds)
    x_i   = prox_h^alpha(q^_i)
outer: x~_i^s = (1/K_s) sum_k x_i^(k,s),  K_s = ceil(beta^s n0),
       x_i^(0,s+1) = x_i^(K_s,s).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np

from . import prox as prox_lib
from .algorithm import (DPSVRGHyperParams, DSPGHyperParams, Problem,
                        build_dpsvrg_inner_step, build_dspg_step,
                        build_node_full_grad_fn, build_node_grad_fn)
from .runner import RunHistory, objective_value as _runner_objective, \
    sample_batch as _sample_batch_impl

__all__ = [
    "DPSVRGHyperParams",
    "DSPGHyperParams",
    "build_dpsvrg_inner_step",
    "build_dspg_step",
    "build_node_grad_fn",
    "build_node_full_grad_fn",
    "centralized_prox_gd",
    "RunHistory",
]


def _sample_batch(rng: np.random.Generator, data, batch_size: int):
    """Alias of ``runner.sample_batch`` (kept for the frozen legacy oracle)."""
    return _sample_batch_impl(rng, data, batch_size)


def _objective(loss_fn, prox, params, full_data) -> float:
    """Alias of ``runner.objective_value`` (kept for the frozen legacy oracle)."""
    return _runner_objective(loss_fn, prox, params, full_data)


def centralized_prox_gd(loss_fn: Callable, prox: prox_lib.Prox, x0, full_data_flat,
                        alpha: float, num_steps: int) -> tuple[Any, np.ndarray]:
    """Centralized full-batch proximal gradient — used to estimate F(x*) for
    the optimality-gap metric (paper Section V-B)."""
    g = jax.grad(loss_fn)

    @jax.jit
    def step(x):
        gr = g(x, full_data_flat)
        z = jax.tree.map(lambda xi, gi: xi - alpha * gi, x, gr)
        return prox.apply(z, alpha)

    hist = []
    x = x0
    for _ in range(num_steps):
        x = step(x)
        hist.append(float(loss_fn(x, full_data_flat) + prox.value(x)))
    return x, np.array(hist)
