"""DPSVRG (paper Algorithm 1) and the DSPG baseline — thin wrappers.

The algorithms themselves now live behind the unified protocol in
``repro.core.algorithm`` (state/step/outer + declarative metadata) and are
driven by the single generic ``repro.core.runner.run`` loop, which owns batch
sampling, time-varying gossip scheduling, metric recording, and the optional
``lax.scan`` fast path.  This module keeps the historical entry points:

* ``DPSVRGHyperParams`` / ``DSPGHyperParams`` — canonical home is
  ``core.algorithm``; re-exported here.
* ``build_dpsvrg_inner_step`` / ``build_dspg_step`` / ``build_node_grad_fn``
  / ``build_node_full_grad_fn`` — re-exported step builders (also used by
  ``core.inexact`` and the kernels' reference paths).
* ``dpsvrg_run`` / ``dspg_run`` — **deprecated** compatibility wrappers over
  ``runner.run``; seed-identical histories to the pre-refactor loops.
  New code should build an ``Algorithm`` (``algorithm.ALGORITHMS``) and call
  ``runner.run`` directly, which also exposes the scan fast path and
  pluggable extra metric recorders.

Algorithm 1 (per node i, inner step k of outer round s):
    v_i   = grad_B f_i(x_i) - grad_B f_i(x~_i) + full_grad_i(x~_i)
    q_i   = x_i - alpha * v_i
    q^_i  = sum_j Phi^(k,s)_{ij} q_j          (multi-consensus: k gossip rounds)
    x_i   = prox_h^alpha(q^_i)
outer: x~_i^s = (1/K_s) sum_k x_i^(k,s),  K_s = ceil(beta^s n0),
       x_i^(0,s+1) = x_i^(K_s,s).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import graphs, prox as prox_lib, runner as runner_lib
from .algorithm import (DPSVRGHyperParams, DSPGHyperParams, Problem,
                        build_dpsvrg_inner_step, build_dspg_step,
                        build_node_full_grad_fn, build_node_grad_fn,
                        dpsvrg_algorithm, dspg_algorithm)
from .runner import RunHistory, objective_value as _runner_objective, \
    sample_batch as _sample_batch_impl

__all__ = [
    "DPSVRGHyperParams",
    "DSPGHyperParams",
    "build_dpsvrg_inner_step",
    "build_dspg_step",
    "build_node_grad_fn",
    "build_node_full_grad_fn",
    "dpsvrg_run",
    "dspg_run",
    "centralized_prox_gd",
    "RunHistory",
]


def _sample_batch(rng: np.random.Generator, data, batch_size: int):
    """Deprecated alias of ``runner.sample_batch`` (kept for old imports)."""
    return _sample_batch_impl(rng, data, batch_size)


def _objective(loss_fn, prox, params, full_data) -> float:
    """Deprecated alias of ``runner.objective_value``."""
    return _runner_objective(loss_fn, prox, params, full_data)


def dpsvrg_run(loss_fn: Callable,
               prox: prox_lib.Prox,
               x0_stacked,
               full_data,
               schedule: graphs.MixingSchedule,
               hp: DPSVRGHyperParams,
               seed: int = 0,
               record_every: int = 1,
               objective_fn: Callable | None = None,
               scan: bool = False) -> tuple[Any, RunHistory]:
    """Deprecated wrapper: faithful Algorithm 1 through the unified runner.

    ``full_data`` leaves: (m, n, ...) per-node data.  The snapshot x~^s for
    the next outer round is the *tail average* of the inner iterates (line
    13), not the final iterate; the final iterate carries over as x^(0,s+1)
    (line 14).  ``scan=True`` enables the chunked ``lax.scan`` fast path.
    """
    problem = Problem(loss_fn, prox, x0_stacked, full_data, objective_fn)
    algo = dpsvrg_algorithm(problem, hp)
    res = runner_lib.run(algo, problem, schedule, seed=seed,
                         record_every=record_every, scan=scan)
    return res.params, res.history


def dspg_run(loss_fn: Callable,
             prox: prox_lib.Prox,
             x0_stacked,
             full_data,
             schedule: graphs.MixingSchedule,
             hp: DSPGHyperParams,
             num_steps: int,
             seed: int = 0,
             record_every: int = 10,
             objective_fn: Callable | None = None,
             scan: bool = False) -> tuple[Any, RunHistory]:
    """Deprecated wrapper: DSPG baseline through the unified runner."""
    problem = Problem(loss_fn, prox, x0_stacked, full_data, objective_fn)
    algo = dspg_algorithm(problem, hp, num_steps)
    res = runner_lib.run(algo, problem, schedule, seed=seed,
                         record_every=record_every, scan=scan)
    return res.params, res.history


def centralized_prox_gd(loss_fn: Callable, prox: prox_lib.Prox, x0, full_data_flat,
                        alpha: float, num_steps: int) -> tuple[Any, np.ndarray]:
    """Centralized full-batch proximal gradient — used to estimate F(x*) for
    the optimality-gap metric (paper Section V-B)."""
    g = jax.grad(loss_fn)

    @jax.jit
    def step(x):
        gr = g(x, full_data_flat)
        z = jax.tree.map(lambda xi, gi: xi - alpha * gi, x, gr)
        return prox.apply(z, alpha)

    hist = []
    x = x0
    for _ in range(num_steps):
        x = step(x)
        hist.append(float(loss_fn(x, full_data_flat) + prox.value(x)))
    return x, np.array(hist)
