"""DPSVRG (paper Algorithm 1) and baselines (DSPG, DPG, centralized PGD).

The module is purely functional: step builders consume a per-node minibatch
gradient function and return jitted steps over *stacked* parameters (leading
node axis of size m).  The same builders drive both the paper-faithful
logistic-regression reproduction and the LM-scale trainer in
``repro.train.steps`` — DPSVRG is the framework's decentralized data-parallel
training rule, not a one-off script.

Algorithm 1 (per node i, inner step k of outer round s):
    v_i   = grad_B f_i(x_i) - grad_B f_i(x~_i) + full_grad_i(x~_i)
    q_i   = x_i - alpha * v_i
    q^_i  = sum_j Phi^(k,s)_{ij} q_j          (multi-consensus: k gossip rounds)
    x_i   = prox_h^alpha(q^_i)
outer: x~_i^s = (1/K_s) sum_k x_i^(k,s),  K_s = ceil(beta^s n0),
       x_i^(0,s+1) = x_i^(K_s,s).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import gossip, graphs, prox as prox_lib, schedules, svrg

__all__ = [
    "DPSVRGHyperParams",
    "DSPGHyperParams",
    "build_dpsvrg_inner_step",
    "build_dspg_step",
    "build_node_grad_fn",
    "build_node_full_grad_fn",
    "dpsvrg_run",
    "dspg_run",
    "centralized_prox_gd",
    "RunHistory",
]


# ---------------------------------------------------------------------------
# Hyper-parameters
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DPSVRGHyperParams:
    alpha: float = 0.01          # constant step size (the VR payoff)
    beta: float = 1.07           # inner-loop growth base
    n0: int = 8                  # initial inner-loop length
    num_outer: int = 30          # S
    batch_size: int = 1          # paper uses single-sample inner steps
    k_max: int | None = None     # multi-consensus cap (None = faithful, k rounds at step k)
    single_consensus: bool = False  # Fig.3 ablation: one gossip round per step
    compress_bits: int | None = None  # int-quantized gossip w/ error feedback


@dataclasses.dataclass(frozen=True)
class DSPGHyperParams:
    alpha0: float = 0.01
    decay: float = 0.5           # alpha_k = alpha0 / (k+1)^decay
    batch_size: int = 1
    constant_step: bool = False  # with a constant step DSPG stalls (inexact convergence)


# ---------------------------------------------------------------------------
# Gradient function builders (stacked over nodes via vmap)
# ---------------------------------------------------------------------------

def build_node_grad_fn(loss_fn: Callable) -> Callable:
    """loss_fn(params, batch)->scalar  =>  grad over stacked params.

    Stacked signature: params leaves (m, ...), batch leaves (m, B, ...).
    vmap over the node axis keeps each node's gradient private, exactly as in
    decentralized learning — under GSPMD the vmapped axis is the node mesh
    axis, so no cross-node communication happens here.
    """
    g = jax.grad(loss_fn)
    return jax.vmap(g)


def build_node_full_grad_fn(loss_fn: Callable, full_batch) -> Callable:
    """Full local gradient closure over each node's entire dataset."""
    g = jax.vmap(jax.grad(loss_fn))

    def full_grad(params):
        return g(params, full_batch)

    return full_grad


# ---------------------------------------------------------------------------
# Jitted steps
# ---------------------------------------------------------------------------

def build_dpsvrg_inner_step(loss_fn: Callable, prox: prox_lib.Prox,
                            compress_bits: int | None = None):
    """Returns jitted ``step(params, svrg_state, batch, phi, alpha[, cstate])``
    implementing Algorithm 1 lines 7-11 for all nodes at once.  With
    ``compress_bits``, gossip carries quantized iterates with error feedback
    (core.compression) and the step threads the compression state.
    """
    node_grad = build_node_grad_fn(loss_fn)

    if compress_bits is None:
        @jax.jit
        def step(params, svrg_state, batch, phi, alpha):
            v = svrg.corrected_gradient(node_grad, params, svrg_state, batch)
            q = jax.tree.map(lambda x, vi: x - alpha * vi, params, v)
            q_hat = gossip.mix_stacked(phi, q)
            x = prox.apply(q_hat, alpha)
            return x

        return step

    from . import compression

    @jax.jit
    def step_c(params, svrg_state, batch, phi, alpha, cstate):
        v = svrg.corrected_gradient(node_grad, params, svrg_state, batch)
        q = jax.tree.map(lambda x, vi: x - alpha * vi, params, v)
        q_hat, cstate = compression.compressed_mix(phi, q, cstate,
                                                   bits=compress_bits)
        x = prox.apply(q_hat, alpha)
        return x, cstate

    return step_c


def build_dspg_step(loss_fn: Callable, prox: prox_lib.Prox):
    """DSPG [paper ref. 11]: plain stochastic gradient + single gossip + prox,
    decaying step size."""
    node_grad = build_node_grad_fn(loss_fn)

    @jax.jit
    def step(params, batch, w, alpha):
        g = node_grad(params, batch)
        q = jax.tree.map(lambda x, gi: x - alpha * gi, params, g)
        q_hat = gossip.mix_stacked(w, q)
        x = prox.apply(q_hat, alpha)
        return x

    return step


# ---------------------------------------------------------------------------
# Host-driven runs (paper-faithful reproduction scale)
# ---------------------------------------------------------------------------

class RunHistory(NamedTuple):
    objective: np.ndarray          # F(x_bar) per recorded point
    consensus: np.ndarray          # mean ||x_i - x_bar||
    epochs: np.ndarray             # effective dataset passes at each point
    comm_rounds: np.ndarray        # cumulative gossip rounds
    steps: np.ndarray              # cumulative inner steps


def _sample_batch(rng: np.random.Generator, data, batch_size: int):
    """Sample per-node minibatch indices and gather. data leaves: (m, n, ...)."""
    first = jax.tree.leaves(data)[0]
    m, n = first.shape[0], first.shape[1]
    idx = rng.integers(0, n, size=(m, batch_size))
    return jax.tree.map(lambda a: np.take_along_axis(
        a, idx.reshape(m, batch_size, *([1] * (a.ndim - 2))), axis=1), data)


def _objective(loss_fn, prox, params, full_data) -> float:
    """F(x_bar) = (1/m) sum_i f_i(x_bar) + h(x_bar)."""
    xbar = gossip.node_mean(params)
    m = jax.tree.leaves(params)[0].shape[0]
    xbar_st = gossip.stack_tree(xbar, m)
    losses = jax.vmap(loss_fn)(xbar_st, full_data)
    return float(jnp.mean(losses) + prox.value(xbar))


def dpsvrg_run(loss_fn: Callable,
               prox: prox_lib.Prox,
               x0_stacked,
               full_data,
               schedule: graphs.MixingSchedule,
               hp: DPSVRGHyperParams,
               seed: int = 0,
               record_every: int = 1,
               objective_fn: Callable | None = None) -> tuple[Any, RunHistory]:
    """Faithful Algorithm 1.  ``full_data`` leaves: (m, n, ...) per-node data.

    The snapshot x~^s for the next outer round is the *tail average* of the
    inner iterates (line 13), not the final iterate; the final iterate
    carries over as x^(0,s+1) (line 14).
    """
    rng = np.random.default_rng(seed)
    inner_step = build_dpsvrg_inner_step(loss_fn, prox,
                                         compress_bits=hp.compress_bits)
    full_grad_fn = build_node_full_grad_fn(loss_fn, full_data)
    obj = objective_fn or (lambda p: _objective(loss_fn, prox, p, full_data))
    cstate = None
    if hp.compress_bits is not None:
        from . import compression
        cstate = compression.init_state(x0_stacked)

    m = jax.tree.leaves(x0_stacked)[0].shape[0]
    n = jax.tree.leaves(full_data)[0].shape[1]
    params = x0_stacked           # x^(0,1)
    snapshot_point = x0_stacked   # x~^0

    hist_obj, hist_cons, hist_ep, hist_comm, hist_steps = [], [], [], [], []
    grad_evals = 0       # single-sample gradient evaluations (epoch metric)
    comm_rounds = 0
    total_steps = 0
    slot = 0             # time-varying schedule position

    def record():
        hist_obj.append(obj(params))
        hist_cons.append(graphs.consensus_distance(
            np.stack([np.concatenate([np.ravel(l[i]) for l in jax.tree.leaves(params)])
                      for i in range(m)])))
        hist_ep.append(grad_evals / float(m * n))
        hist_comm.append(comm_rounds)
        hist_steps.append(total_steps)

    record()
    ks = schedules.inner_loop_lengths(hp.beta, hp.n0, hp.num_outer)
    for s, K_s in enumerate(ks, start=1):
        # outer: full local gradient at the snapshot (line 5)
        state = svrg.SvrgState(snapshot=snapshot_point,
                               full_grad=full_grad_fn(snapshot_point))
        grad_evals += m * n
        inner_sum = jax.tree.map(jnp.zeros_like, params)
        for k in range(1, K_s + 1):
            batch = _sample_batch(rng, full_data, hp.batch_size)
            rounds = 1 if hp.single_consensus else (
                k if hp.k_max is None else min(k, hp.k_max))
            phi = schedule.consensus_rounds(slot, rounds)
            slot += rounds
            comm_rounds += rounds
            if cstate is None:
                params = inner_step(params, state, batch,
                                    jnp.asarray(phi, jnp.float32),
                                    jnp.float32(hp.alpha))
            else:
                params, cstate = inner_step(params, state, batch,
                                            jnp.asarray(phi, jnp.float32),
                                            jnp.float32(hp.alpha), cstate)
            inner_sum = svrg.tree_add(inner_sum, params)
            grad_evals += 2 * m * hp.batch_size
            total_steps += 1
            if record_every and (k % record_every == 0):
                record()
        # x~^s = tail average (line 13); params carries over (line 14)
        snapshot_point = jax.tree.map(lambda acc: acc / K_s, inner_sum)
        if not record_every:
            record()   # one point per outer round
    if record_every:
        record()
    return params, RunHistory(np.array(hist_obj), np.array(hist_cons),
                              np.array(hist_ep), np.array(hist_comm),
                              np.array(hist_steps))


def dspg_run(loss_fn: Callable,
             prox: prox_lib.Prox,
             x0_stacked,
             full_data,
             schedule: graphs.MixingSchedule,
             hp: DSPGHyperParams,
             num_steps: int,
             seed: int = 0,
             record_every: int = 10,
             objective_fn: Callable | None = None) -> tuple[Any, RunHistory]:
    """DSPG baseline: one stochastic prox-gradient + one gossip per step."""
    rng = np.random.default_rng(seed)
    step_fn = build_dspg_step(loss_fn, prox)
    obj = objective_fn or (lambda p: _objective(loss_fn, prox, p, full_data))
    step_size = (schedules.constant(hp.alpha0) if hp.constant_step
                 else schedules.dspg_stepsize(hp.alpha0, hp.decay))

    m = jax.tree.leaves(x0_stacked)[0].shape[0]
    n = jax.tree.leaves(full_data)[0].shape[1]
    params = x0_stacked
    hist_obj, hist_cons, hist_ep, hist_comm, hist_steps = [], [], [], [], []
    grad_evals = 0

    def record(t):
        hist_obj.append(obj(params))
        hist_cons.append(graphs.consensus_distance(
            np.stack([np.concatenate([np.ravel(l[i]) for l in jax.tree.leaves(params)])
                      for i in range(m)])))
        hist_ep.append(grad_evals / float(m * n))
        hist_comm.append(t)
        hist_steps.append(t)

    record(0)
    for t in range(1, num_steps + 1):
        batch = _sample_batch(rng, full_data, hp.batch_size)
        w = schedule.matrix(t)
        params = step_fn(params, batch, jnp.asarray(w, jnp.float32),
                         jnp.float32(step_size(t)))
        grad_evals += m * hp.batch_size
        if t % record_every == 0 or t == num_steps:
            record(t)
    return params, RunHistory(np.array(hist_obj), np.array(hist_cons),
                              np.array(hist_ep), np.array(hist_comm),
                              np.array(hist_steps))


def centralized_prox_gd(loss_fn: Callable, prox: prox_lib.Prox, x0, full_data_flat,
                        alpha: float, num_steps: int) -> tuple[Any, np.ndarray]:
    """Centralized full-batch proximal gradient — used to estimate F(x*) for
    the optimality-gap metric (paper Section V-B)."""
    g = jax.grad(loss_fn)

    @jax.jit
    def step(x):
        gr = g(x, full_data_flat)
        z = jax.tree.map(lambda xi, gi: xi - alpha * gi, x, gr)
        return prox.apply(z, alpha)

    hist = []
    x = x0
    for _ in range(num_steps):
        x = step(x)
        hist.append(float(loss_fn(x, full_data_flat) + prox.value(x)))
    return x, np.array(hist)
