"""One execution specification for every driver entry point.

The execution surface grew one keyword at a time — ``runner.run(scan=,
resident=, sampling=, device_transitions=, kernel=, gossip=, mesh=)``,
mirrored (inconsistently) by ``runner.run_sweep`` and
``train/trainer.train_loop`` — and the mesh scale-out work adds ``shard=``
on top.  :class:`ExecSpec` packages that whole axis as ONE immutable value
consumed by all three drivers::

    from repro.core.exec_spec import ExecSpec
    runner.run(algo, problem, sched, ExecSpec(resident=True,
                                              sampling="device"))
    runner.run_sweep(build, grid, sched, ExecSpec(resident=True,
                                                  shard="cells"))
    trainer.train_loop(cfg, prox, sched, data, tc,
                       exec=ExecSpec(resident=True))

Field-for-field it matches the legacy keywords, plus ``shard``:

* ``scan`` / ``resident`` — execution path (host loop, ``lax.scan``
  chunks, or fully device-resident).
* ``sampling`` — "host" | "device" minibatch index stream (resident only
  for "device").
* ``device_transitions`` — fold outer-round transitions into the compiled
  resident chunks ("auto" | True | False).
* ``kernel`` — "xla" | "pallas" | "auto" resident chunk body.
* ``gossip`` — transport backend name / instance / "auto".
* ``mesh`` — device mesh for mesh-collective transports AND for sharded
  execution.
* ``shard`` — ``None`` | ``"cells"`` (partition a batched sweep's cell
  axis over the mesh; ``run_sweep`` only) | ``"nodes"`` (partition the
  stacked ``(m, d)`` node axis of a resident run over the mesh;
  ``runner.run`` only).

Cross-field constraints are validated at construction, so an invalid
combination fails where the spec is BUILT, not steps later inside a driver.

The legacy keywords keep working for one release through
:func:`resolve_exec`: passing any of them emits a ``DeprecationWarning``
(the suite's deprecation-as-error CI leg keeps the repo itself clean), and
passing BOTH a spec and a legacy keyword raises — a conflicting split
specification has no right answer.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

__all__ = ["ExecSpec", "UNSET", "resolve_exec"]


class _Unset:
    """Sentinel distinguishing 'keyword not passed' from any real value."""

    __slots__ = ()

    def __repr__(self):
        return "<unset>"


UNSET = _Unset()

_SAMPLING = ("host", "device")
_KERNELS = ("xla", "pallas", "auto")
_SHARDS = (None, "cells", "nodes")
_TRANSITIONS = ("auto", True, False)


@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """How a run executes — every path/transport/mesh choice in one value.

    Defaults reproduce ``runner.run``'s host loop.  ``run_sweep`` defaults
    to ``ExecSpec(resident=True)`` when no spec is passed (batched sweeps
    are resident by construction); ``train_loop`` defaults to its
    ``TrainerConfig`` fields.
    """

    scan: bool = False
    resident: bool = False
    sampling: str = "host"
    device_transitions: Any = "auto"
    kernel: str = "xla"
    gossip: Any = "auto"
    mesh: Any = None
    shard: "str | None" = None

    def __post_init__(self):
        if self.sampling not in _SAMPLING:
            raise ValueError(f"sampling must be 'host' or 'device', got "
                             f"{self.sampling!r}")
        if self.kernel not in _KERNELS:
            raise ValueError(f"kernel must be 'xla', 'pallas', or 'auto', "
                             f"got {self.kernel!r}")
        if self.shard not in _SHARDS:
            raise ValueError(f"shard must be None, 'cells', or 'nodes', "
                             f"got {self.shard!r}")
        if not any(self.device_transitions is t for t in _TRANSITIONS):
            raise ValueError(f"device_transitions must be 'auto', True, or "
                             f"False, got {self.device_transitions!r}")
        if self.sampling == "device" and not self.resident:
            raise ValueError("sampling='device' gathers minibatches inside "
                             "the compiled chunk body — it requires "
                             "resident=True")
        if self.device_transitions is True and not self.resident:
            raise ValueError("device_transitions folds outer rounds into "
                             "the compiled resident chunks — it requires "
                             "resident=True")
        if self.kernel != "xla" and not self.resident:
            raise ValueError("kernel='pallas'/'auto' swaps the fused body "
                             "into the compiled resident chunks — it "
                             "requires resident=True")
        if self.shard is not None and not self.resident:
            raise ValueError(f"shard={self.shard!r} partitions the "
                             f"device-resident program over a mesh — it "
                             f"requires resident=True")

    def replace(self, **kw) -> "ExecSpec":
        """A copy with the given fields changed (re-validated)."""
        return dataclasses.replace(self, **kw)


def resolve_exec(spec: "ExecSpec | None", caller: str,
                 defaults: "dict | None" = None, **legacy) -> ExecSpec:
    """Merge a driver call's ``exec=`` spec with its legacy keywords.

    ``legacy`` maps ExecSpec field names to the driver's received keyword
    values, with :data:`UNSET` meaning "not passed".  Exactly one source
    wins:

    * spec given, no legacy keyword passed  -> the spec, as is;
    * spec given AND a legacy keyword passed -> ``ValueError`` (conflict);
    * legacy keywords only -> ``DeprecationWarning`` naming them, then an
      ``ExecSpec`` built from ``defaults`` overlaid with the passed values
      (one-release shim, like the retired ``gossip_mode=`` keyword);
    * neither -> ``ExecSpec(**defaults)``.

    ``defaults`` carries the driver's historical defaults where they differ
    from ExecSpec's (``run_sweep`` was resident by default; ``train_loop``
    defaults to its TrainerConfig fields).
    """
    given = {k: v for k, v in legacy.items() if v is not UNSET}
    if spec is not None:
        if not isinstance(spec, ExecSpec):
            raise TypeError(f"{caller}: exec must be an ExecSpec, got "
                            f"{type(spec).__name__}")
        if given:
            raise ValueError(
                f"{caller}: conflicting execution settings — both exec= and "
                f"the legacy keyword(s) {sorted(given)} were passed; fold "
                f"everything into the ExecSpec")
        return spec
    fields = dict(defaults or {})
    if given:
        kwargs = ", ".join(f"{k}=..." for k in sorted(given))
        warnings.warn(
            f"{caller}({kwargs}) is deprecated; pass "
            f"exec=ExecSpec({kwargs}) instead (repro.core.exec_spec)",
            DeprecationWarning, stacklevel=3)
        fields.update(given)
    return ExecSpec(**fields)
