"""Inexact Prox-SVRG (paper Algorithm 2) and an *executable* Theorem 1.

Algorithm 2 is the centralized reformulation of DPSVRG: a virtual node holds
the average parameter and runs Prox-SVRG with two injected error sequences —
the gradient error ``e^(k,s)`` (Eq. 10a) and the proximal error ``eps^(k,s)``
(Eq. 10b) — which absorb the dissensus of the decentralized copies.

Both entry points now run through the unified ``Algorithm``/``runner.run``
protocol instead of bespoke loops:

* ``inexact_prox_svrg_algorithm`` — Algorithm 2 as a protocol plugin (one
  virtual node: stacked trees with m = 1, identity gossip), registered in
  ``algorithm.ALGORITHMS`` as ``"inexact_prox_svrg"``.  Error injection is
  part of the step (the state carries the global step counter), so the same
  sampling, scheduling, and recording machinery drives it as Algorithm 1;
  with a jax-traceable ``grad_error_fn`` (or none) it runs on the
  ``lax.scan`` fast path too.
* ``inexact_prox_svrg_run`` — thin convenience entry over ``runner.run``
  with the historical (final_params, objective_history) return shape.
* ``verify_theorem1`` — runs DPSVRG (Algorithm 1) through ``runner.run``
  with a diagnostic step wrapper that checks, step by step, the constructive
  content of Theorem 1:
    (i)  with ``e`` from Eq. (10a), the Algorithm-2 gradient step reproduces
         the node-average pre-consensus iterate:  q̄ = x̄ − α(v + e);
    (ii) gossip preserves the node average (doubly stochastic Φ): mean(q̂)=q̄;
    (iii) x̄^(k,s) is an ε-inexact prox of q̄ with ε from Eq. (10b): the
          inexactness inequality (9) holds with that ε, and ε → 0 as the
          copies reach consensus.
  Returns per-step diagnostics so tests can assert all three claims and the
  summability of the error sequences (Assumption 6 / Theorem 3's Eq. 25).
  The Eq. (10b) epsilon needs a subgradient p ∈ ∂h(x̄): it is taken from the
  prox's registered ``subgrad`` (l1, elastic net, group lasso, ...) and the
  check raises loudly for proxes without one instead of silently assuming
  h = 0.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import (algorithm as algorithm_lib, gossip, graphs, prox as prox_lib,
               runner as runner_lib, schedules, svrg)
from .algorithm import (AlgoMeta, Algorithm, DPSVRGHyperParams, Problem,
                        build_node_full_grad_fn, build_node_grad_fn,
                        prox_gossip_update)

__all__ = [
    "InexactHyperParams",
    "inexact_prox_svrg_algorithm",
    "inexact_prox_svrg_run",
    "verify_theorem1",
    "Theorem1Diagnostics",
]


# ---------------------------------------------------------------------------
# Algorithm 2 on the protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InexactHyperParams:
    """Algorithm 2 shares Algorithm 1's loop geometry (K_s = ceil(beta^s n0),
    constant step, tail-average snapshots) on a single virtual node."""
    alpha: float = 0.01
    beta: float = 1.07
    n0: int = 8
    num_outer: int = 30
    batch_size: int = 1


class InexactState(NamedTuple):
    params: Any                  # stacked (1, ...) virtual-node iterate
    anchor: Any                  # snapshot point for the NEXT refresh
    est: svrg.SvrgState | None   # current snapshot + full gradient
    inner_sum: Any               # tail-average accumulator
    t: Any                       # global step counter (drives error injection)


def inexact_prox_svrg_algorithm(problem: Problem, hp: InexactHyperParams,
                                grad_error_fn: Callable | None = None
                                ) -> Algorithm:
    """Paper Algorithm 2 as an :class:`Algorithm` plugin.

    ``problem`` is a standard stacked problem with m = 1 (the virtual node
    holding the average); drive it with an identity schedule, e.g.
    ``graphs.static_schedule(np.eye(1))``.  ``grad_error_fn(t, params) ->
    pytree`` injects the Eq. (10a) gradient error e^(k,s) at global step t
    (0-based) given the UNSTACKED iterate; None means exact.  Host-side
    (non-traceable) error models require the host loop (the default
    ``ExecSpec()``); the
    proximal error eps^(k,s) is not injected here (our prox operators are
    exact closed forms; Algorithm 2's eps models the *decentralized* prox
    gap, which ``verify_theorem1`` measures on the real DPSVRG run instead).
    """
    full_grad_fn = build_node_full_grad_fn(problem.loss_fn, problem.full_data)
    prox = problem.prox

    def _make_inner():
        node_grad = build_node_grad_fn(problem.loss_fn)

        @jax.jit
        def _step(params, est, batch, phi, alpha, err):
            v = svrg.corrected_gradient(node_grad, params, est, batch)
            v = svrg.tree_add(v, err)
            return prox_gossip_update(params, v, phi, alpha, prox)

        return _step

    _step = algorithm_lib._shared_step(
        ("inexact_inner", problem.loss_fn, prox), _make_inner)

    def _zeros(tree):
        return jax.tree.map(jnp.zeros_like, tree)

    def init():
        return InexactState(params=problem.x0, anchor=problem.x0, est=None,
                            inner_sum=_zeros(problem.x0),
                            t=jnp.asarray(0, jnp.int32))

    def outer(state):
        est = svrg.SvrgState(snapshot=state.anchor,
                             full_grad=full_grad_fn(state.anchor))
        return state._replace(est=est, inner_sum=_zeros(state.params))

    def make_step():
        def step(state, batch, phi, alpha):
            if grad_error_fn is None:
                err = _zeros(state.params)
            else:
                err = grad_error_fn(state.t,
                                    gossip.unstack_tree(state.params))
                err = jax.tree.map(lambda e: jnp.asarray(e)[None], err)
            params = _step(state.params, state.est, batch, phi, alpha, err)
            return state._replace(
                params=params, t=state.t + 1,
                inner_sum=svrg.tree_add(state.inner_sum, params))
        return step

    step = algorithm_lib._shared_step(
        ("inexact_proto_step", _step, grad_error_fn), make_step)

    def end_outer(state, K):
        return state._replace(
            anchor=jax.tree.map(lambda acc: acc / K, state.inner_sum))

    # the traceable outer-transition contract (device-side transitions /
    # batched sweeps): same refresh with the dataset passed explicitly
    def _make_outer_traced():
        node_grad = build_node_grad_fn(problem.loss_fn)

        def outer_traced(state, full_data):
            est = svrg.SvrgState(snapshot=state.anchor,
                                 full_grad=node_grad(state.anchor, full_data))
            return state._replace(est=est, inner_sum=_zeros(state.params))

        return outer_traced

    outer_traced = algorithm_lib._shared_step(
        ("inexact_outer_traced", problem.loss_fn), _make_outer_traced)

    meta = AlgoMeta(
        name="inexact_prox_svrg",
        stepsize=schedules.constant(hp.alpha),
        outer_lengths=tuple(
            schedules.inner_loop_lengths(hp.beta, hp.n0, hp.num_outer)),
        batch_size=hp.batch_size,
        step_grad_factor=2,
        outer_full_grad=True,
        record_key="round",
        final_record=True,
    )
    return Algorithm(
        meta=meta, init=init, step=step, outer=outer, end_outer=end_outer,
        outer_traced=outer_traced,
        end_outer_traced=algorithm_lib._tail_average_end_outer_traced(),
        device_state=algorithm_lib._svrg_placeholder_state)


# Registered alongside the decentralized methods: Algorithm 2 is just another
# protocol plugin to the runner (import of this module wires it up).
algorithm_lib.ALGORITHMS["inexact_prox_svrg"] = inexact_prox_svrg_algorithm


def inexact_prox_svrg_run(loss_fn: Callable,
                          prox: prox_lib.Prox,
                          x0,
                          full_data_flat,
                          alpha: float,
                          beta: float,
                          n0: int,
                          num_outer: int,
                          batch_size: int = 1,
                          grad_error_fn: Callable | None = None,
                          seed: int = 0,
                          objective_fn: Callable | None = None):
    """Centralized Algorithm 2 through the unified runner.

    ``full_data_flat`` leaves: (n, ...); ``x0`` and ``grad_error_fn`` use the
    unstacked (centralized) parameter shape.  Returns
    (final_params, objective_history np.ndarray over inner steps).
    """
    x0_st = jax.tree.map(lambda a: jnp.asarray(a)[None], x0)
    data_st = jax.tree.map(lambda a: jnp.asarray(a)[None], full_data_flat)
    obj = None
    if objective_fn is not None:
        obj = lambda p_st: objective_fn(gossip.unstack_tree(p_st))
    problem = Problem(loss_fn, prox, x0_st, data_st, obj)
    hp = InexactHyperParams(alpha=alpha, beta=beta, n0=n0,
                            num_outer=num_outer, batch_size=batch_size)
    algo = inexact_prox_svrg_algorithm(problem, hp,
                                       grad_error_fn=grad_error_fn)
    sched = graphs.static_schedule(np.eye(1), name="centralized")
    res = runner_lib.run(algo, problem, sched, seed=seed, record_every=1)
    return gossip.unstack_tree(res.params), res.history.objective


# ---------------------------------------------------------------------------
# Executable Theorem 1: a diagnostic step wrapper over Algorithm 1
# ---------------------------------------------------------------------------

class Theorem1Diagnostics(NamedTuple):
    qbar_residual: np.ndarray   # || mean_i q_i  -  (x̄_prev - α(v+e)) ||  (claim i)
    mix_mean_residual: np.ndarray  # || mean_i q̂_i - mean_i q_i ||        (claim ii)
    eps: np.ndarray             # ε^(k,s) from Eq. (10b)
    ineq9_slack: np.ndarray     # RHS(9) - LHS(9) with that ε (≥ 0 ⇒ claim iii)
    grad_err_norm: np.ndarray   # ||e^(k,s)||  (for Assumption-6 summability)
    consensus: np.ndarray       # mean ||x_i - x̄||


def _tree_flat(tree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(tree)])


def _prox_subgradient(prox: prox_lib.Prox, tree):
    """A canonical p ∈ ∂h(x) from the prox's registered subgradient.

    Raises for proxes without one: Eq. (10b)'s eps (and the inequality-(9)
    slack built on it) is WRONG if h's subgradient is silently taken as 0 —
    the historical bug this replaces did exactly that for every non-l1 prox.
    """
    if prox.subgrad is None:
        raise NotImplementedError(
            f"prox '{prox.name}' registers no subgradient; Theorem-1's "
            f"Eq. (10b) eps needs p ∈ ∂h(x̄) — add a `subgrad` to the Prox "
            f"or use one of l1 / elastic_net / group_lasso / squared_l2")
    return prox.subgrad(tree)


def verify_theorem1(loss_fn: Callable,
                    prox: prox_lib.Prox,
                    x0_stacked,
                    full_data,
                    schedule: graphs.MixingSchedule,
                    hp: DPSVRGHyperParams,
                    seed: int = 0) -> Theorem1Diagnostics:
    """Run Algorithm 1 via ``runner.run`` and check the Theorem-1
    construction at every inner step.

    Implemented as a step wrapper around the stock ``dpsvrg_algorithm``: the
    wrapped step first advances the real algorithm, then recomputes the
    step's intermediates (v_i, q_i, q̂_i) from the same (state, batch, phi)
    to evaluate claims (i)-(iii).  Sampling, scheduling, and accounting are
    therefore EXACTLY the production runner's — the diagnostics measure the
    real Algorithm-1 trajectory, not a parallel reimplementation.  Host loop
    only (the checks are host-side); requires uncompressed gossip.
    """
    if hp.compress_bits is not None:
        raise ValueError("verify_theorem1 checks the exact-gossip Theorem-1 "
                         "construction; quantized gossip (compress_bits) "
                         "does not preserve the node mean per step")
    node_grad = build_node_grad_fn(loss_fn)
    full_grad_fn = build_node_full_grad_fn(loss_fn, full_data)
    m = jax.tree.leaves(x0_stacked)[0].shape[0]

    problem = Problem(loss_fn, prox, x0_stacked, full_data)
    algo = algorithm_lib.dpsvrg_algorithm(problem, hp)
    base_step = algo.step

    d_qbar, d_mix, d_eps, d_slack, d_enorm, d_cons = [], [], [], [], [], []

    def diagnostic_step(state, batch, phi, alpha):
        new_state = base_step(state, batch, phi, alpha)

        params, est = state.params, state.est
        xbar_prev = gossip.node_mean(params)

        # --- Algorithm 1 step intermediates, recomputed -------------------
        v_i = svrg.corrected_gradient(node_grad, params, est, batch)
        q_i = jax.tree.map(lambda x, vv: x - hp.alpha * vv, params, v_i)
        q_hat = gossip.mix_stacked(phi, q_i)
        x_new = new_state.params

        # --- Theorem-1 claim (i): centralized v + e reproduce q̄ ----------
        # v^(k,s) of Algorithm 2 uses the same samples at the averaged
        # iterates; e^(k,s) (Eq. 10a) is exactly the difference
        # mean_i v_i - v, so q̄ = x̄_prev - α(mean_i v_i) must equal
        # x̄_prev - α(v + e).  We verify Eq. 10a's decomposition directly:
        xbar_prev_st = gossip.stack_tree(xbar_prev, m)
        snapbar = gossip.node_mean(est.snapshot)
        snapbar_st = gossip.stack_tree(snapbar, m)
        g_xbar = node_grad(xbar_prev_st, batch)           # ∇f_i^{l_i}(x̄)
        g_snapbar = node_grad(snapbar_st, batch)          # ∇f_i^{l_i}(x̃)
        full_at_snap_i = est.full_grad                    # ∇f_i(x̃_i)
        full_at_snapbar = full_grad_fn(snapbar_st)        # ∇f_i(x̃)
        g_now = node_grad(params, batch)
        g_snap_i = node_grad(est.snapshot, batch)

        # Eq. (10a): e = mean_i[(∇f_i^l(x_i)-∇f_i^l(x̄))
        #                       + (∇f_i^l(x̃) - ∇f_i^l(x̃_i))
        #                       + (∇f_i(x̃_i) - ∇f_i(x̃))]
        e_tree = jax.tree.map(
            lambda a, b, c, d_, e_, f_: jnp.mean(
                (a - b) + (c - d_) + (e_ - f_), axis=0),
            g_now, g_xbar, g_snapbar, g_snap_i, full_at_snap_i,
            full_at_snapbar)
        # centralized estimator v = mean_i[∇f_i^l(x̄) - ∇f_i^l(x̃) + ∇f_i(x̃)]
        v_central = jax.tree.map(
            lambda a, b, c: jnp.mean(a - b + c, axis=0),
            g_xbar, g_snapbar, full_at_snapbar)
        qbar_from_alg2 = jax.tree.map(
            lambda x, vv, ee: x - hp.alpha * (vv + ee),
            xbar_prev, v_central, e_tree)
        qbar_actual = gossip.node_mean(q_i)
        d_qbar.append(float(svrg.tree_norm(
            svrg.tree_sub(qbar_actual, qbar_from_alg2))))
        d_enorm.append(float(svrg.tree_norm(e_tree)))

        # --- claim (ii): doubly-stochastic mixing preserves the mean ------
        d_mix.append(float(svrg.tree_norm(
            svrg.tree_sub(gossip.node_mean(q_hat), qbar_actual))))

        # --- claim (iii): x̄ is an ε-inexact prox of q̄ --------------------
        xbar_new = gossip.node_mean(x_new)
        y = prox.apply(qbar_actual, hp.alpha)  # exact prox of q̄
        # Eq. (10b): ε = 1/(2α)||x̄-y||² + <x̄-y, (y-q̄)/α + p>, p ∈ ∂h(x̄)
        diff = _tree_flat(svrg.tree_sub(xbar_new, y))
        yq = _tree_flat(svrg.tree_sub(y, qbar_actual))
        p_vec = _tree_flat(_prox_subgradient(prox, xbar_new))
        eps = float(jnp.vdot(diff, diff) / (2 * hp.alpha)
                    + jnp.vdot(diff, yq / hp.alpha + p_vec))
        d_eps.append(eps)
        # inexactness inequality (9):
        # 1/(2α)||x̄-q̄||² + h(x̄) ≤ min_y {...} + ε
        def _proxobj(pt):
            dd = _tree_flat(svrg.tree_sub(pt, qbar_actual))
            return float(jnp.vdot(dd, dd) / (2 * hp.alpha) + prox.value(pt))
        lhs = _proxobj(xbar_new)
        rhs = _proxobj(y) + eps
        d_slack.append(rhs - lhs)

        d_cons.append(graphs.consensus_distance(np.stack(
            [np.asarray(_tree_flat(gossip.unstack_tree(x_new, i)))
             for i in range(m)])))

        return new_state

    wrapped = dataclasses.replace(algo, step=diagnostic_step)
    runner_lib.run(wrapped, problem, schedule, seed=seed, record_every=0)

    return Theorem1Diagnostics(
        qbar_residual=np.array(d_qbar),
        mix_mean_residual=np.array(d_mix),
        eps=np.array(d_eps),
        ineq9_slack=np.array(d_slack),
        grad_err_norm=np.array(d_enorm),
        consensus=np.array(d_cons))
