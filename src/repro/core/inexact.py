"""Inexact Prox-SVRG (paper Algorithm 2) and an *executable* Theorem 1.

Algorithm 2 is the centralized reformulation of DPSVRG: a virtual node holds
the average parameter and runs Prox-SVRG with two injected error sequences —
the gradient error ``e^(k,s)`` (Eq. 10a) and the proximal error ``eps^(k,s)``
(Eq. 10b) — which absorb the dissensus of the decentralized copies.

This module provides:

* ``inexact_prox_svrg_run`` — Algorithm 2 with a pluggable error model
  (zero errors ⇒ exact centralized Prox-SVRG).
* ``verify_theorem1`` — runs DPSVRG (Algorithm 1) while simultaneously
  checking, step by step, the constructive content of Theorem 1:
    (i)  with ``e`` from Eq. (10a), the Algorithm-2 gradient step reproduces
         the node-average pre-consensus iterate:  q̄ = x̄ − α(v + e);
    (ii) gossip preserves the node average (doubly stochastic Φ): mean(q̂)=q̄;
    (iii) x̄^(k,s) is an ε-inexact prox of q̄ with ε from Eq. (10b): the
          inexactness inequality (9) holds with that ε, and ε → 0 as the
          copies reach consensus.
  Returns per-step diagnostics so tests can assert all three claims and the
  summability of the error sequences (Assumption 6 / Theorem 3's Eq. 25).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dpsvrg, gossip, graphs, prox as prox_lib, schedules, svrg

__all__ = ["inexact_prox_svrg_run", "verify_theorem1", "Theorem1Diagnostics"]


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------

def inexact_prox_svrg_run(loss_fn: Callable,
                          prox: prox_lib.Prox,
                          x0,
                          full_data_flat,
                          alpha: float,
                          beta: float,
                          n0: int,
                          num_outer: int,
                          batch_size: int = 1,
                          grad_error_fn: Callable | None = None,
                          seed: int = 0,
                          objective_fn: Callable | None = None):
    """Centralized Algorithm 2.  ``full_data_flat`` leaves: (n, ...).

    ``grad_error_fn(step, params) -> pytree`` injects e^(k,s) (None = exact).
    The proximal error is not injected here (our prox operators are exact
    closed forms; Algorithm 2's eps models the *decentralized* prox gap,
    which ``verify_theorem1`` measures on the real DPSVRG run instead).

    Returns (final_params, objective_history np.ndarray over inner steps).
    """
    rng = np.random.default_rng(seed)
    g = jax.grad(loss_fn)

    @jax.jit
    def step(x, snapshot, mu, batch, err, a):
        v = jax.tree.map(lambda gn, gs, m_: gn - gs + m_,
                         g(x, batch), g(snapshot, batch), mu)
        q = jax.tree.map(lambda xi, vi, ei: xi - a * (vi + ei), x, v, err)
        return prox.apply(q, a)

    n = jax.tree.leaves(full_data_flat)[0].shape[0]
    obj = objective_fn or (
        lambda p: float(loss_fn(p, full_data_flat) + prox.value(p)))

    x = x0
    snapshot = x0
    hist = [obj(x)]
    t = 0
    for s in range(1, num_outer + 1):
        mu = g(snapshot, full_data_flat)
        K_s = int(np.ceil((beta ** s) * n0))
        inner_sum = jax.tree.map(jnp.zeros_like, x)
        for _ in range(K_s):
            idx = rng.integers(0, n, size=(batch_size,))
            batch = jax.tree.map(lambda a_: a_[idx], full_data_flat)
            err = (grad_error_fn(t, x) if grad_error_fn is not None
                   else jax.tree.map(jnp.zeros_like, x))
            x = step(x, snapshot, mu, batch, err, jnp.float32(alpha))
            inner_sum = svrg.tree_add(inner_sum, x)
            hist.append(obj(x))
            t += 1
        snapshot = jax.tree.map(lambda acc: acc / K_s, inner_sum)
    return x, np.array(hist)


# ---------------------------------------------------------------------------
# Executable Theorem 1
# ---------------------------------------------------------------------------

class Theorem1Diagnostics(NamedTuple):
    qbar_residual: np.ndarray   # || mean_i q_i  -  (x̄_prev - α(v+e)) ||  (claim i)
    mix_mean_residual: np.ndarray  # || mean_i q̂_i - mean_i q_i ||        (claim ii)
    eps: np.ndarray             # ε^(k,s) from Eq. (10b)
    ineq9_slack: np.ndarray     # RHS(9) - LHS(9) with that ε (≥ 0 ⇒ claim iii)
    grad_err_norm: np.ndarray   # ||e^(k,s)||  (for Assumption-6 summability)
    consensus: np.ndarray       # mean ||x_i - x̄||


def _tree_flat(tree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(tree)])


def verify_theorem1(loss_fn: Callable,
                    prox: prox_lib.Prox,
                    x0_stacked,
                    full_data,
                    schedule: graphs.MixingSchedule,
                    hp: dpsvrg.DPSVRGHyperParams,
                    seed: int = 0) -> Theorem1Diagnostics:
    """Run Algorithm 1 and check the Theorem-1 construction at every step."""
    rng = np.random.default_rng(seed)
    node_grad = dpsvrg.build_node_grad_fn(loss_fn)
    full_grad_fn = dpsvrg.build_node_full_grad_fn(loss_fn, full_data)

    m = jax.tree.leaves(x0_stacked)[0].shape[0]
    params = x0_stacked
    snapshot_point = x0_stacked
    slot = 0

    d_qbar, d_mix, d_eps, d_slack, d_enorm, d_cons = [], [], [], [], [], []

    ks = schedules.inner_loop_lengths(hp.beta, hp.n0, hp.num_outer)
    for s, K_s in enumerate(ks, start=1):
        state = svrg.SvrgState(snapshot=snapshot_point,
                               full_grad=full_grad_fn(snapshot_point))
        inner_sum = jax.tree.map(jnp.zeros_like, params)
        for k in range(1, K_s + 1):
            batch = dpsvrg._sample_batch(rng, full_data, hp.batch_size)
            rounds = k if hp.k_max is None else min(k, hp.k_max)
            phi = jnp.asarray(schedule.consensus_rounds(slot, rounds), jnp.float32)
            slot += rounds

            xbar_prev = gossip.node_mean(params)

            # --- Algorithm 1 step, with intermediates exposed -------------
            v_i = svrg.corrected_gradient(node_grad, params, state, batch)
            q_i = jax.tree.map(lambda x, vv: x - hp.alpha * vv, params, v_i)
            q_hat = gossip.mix_stacked(phi, q_i)
            x_new = prox.apply(q_hat, hp.alpha)

            # --- Theorem-1 claim (i): centralized v + e reproduce q̄ ------
            # v^(k,s) of Algorithm 2 uses the same samples at the averaged
            # iterates; e^(k,s) (Eq. 10a) is exactly the difference
            # mean_i v_i - v, so q̄ = x̄_prev - α(mean_i v_i) must equal
            # x̄_prev - α(v + e).  We verify Eq. 10a's decomposition directly:
            xbar_prev_st = gossip.stack_tree(xbar_prev, m)
            snapbar = gossip.node_mean(state.snapshot)
            snapbar_st = gossip.stack_tree(snapbar, m)
            g_xbar = node_grad(xbar_prev_st, batch)           # ∇f_i^{l_i}(x̄)
            g_snapbar = node_grad(snapbar_st, batch)          # ∇f_i^{l_i}(x̃)
            full_at_snap_i = state.full_grad                  # ∇f_i(x̃_i)
            full_at_snapbar = full_grad_fn(snapbar_st)        # ∇f_i(x̃)
            g_now = node_grad(params, batch)
            g_snap_i = node_grad(state.snapshot, batch)

            # Eq. (10a): e = mean_i[(∇f_i^l(x_i)-∇f_i^l(x̄))
            #                       + (∇f_i^l(x̃) - ∇f_i^l(x̃_i))
            #                       + (∇f_i(x̃_i) - ∇f_i(x̃))]
            e_tree = jax.tree.map(
                lambda a, b, c, d_, e_, f_: jnp.mean(
                    (a - b) + (c - d_) + (e_ - f_), axis=0),
                g_now, g_xbar, g_snapbar, g_snap_i, full_at_snap_i,
                full_at_snapbar)
            # centralized estimator v = mean_i[∇f_i^l(x̄) - ∇f_i^l(x̃) + ∇f_i(x̃)]
            v_central = jax.tree.map(
                lambda a, b, c: jnp.mean(a - b + c, axis=0),
                g_xbar, g_snapbar, full_at_snapbar)
            qbar_from_alg2 = jax.tree.map(
                lambda x, vv, ee: x - hp.alpha * (vv + ee),
                xbar_prev, v_central, e_tree)
            qbar_actual = gossip.node_mean(q_i)
            d_qbar.append(float(svrg.tree_norm(
                svrg.tree_sub(qbar_actual, qbar_from_alg2))))
            d_enorm.append(float(svrg.tree_norm(e_tree)))

            # --- claim (ii): doubly-stochastic mixing preserves the mean --
            d_mix.append(float(svrg.tree_norm(
                svrg.tree_sub(gossip.node_mean(q_hat), qbar_actual))))

            # --- claim (iii): x̄ is an ε-inexact prox of q̄ ----------------
            xbar_new = gossip.node_mean(x_new)
            y = prox.apply(qbar_actual, hp.alpha)  # exact prox of q̄
            # Eq. (10b): ε = 1/(2α)||x̄-y||² + <x̄-y, (y-q̄)/α + p>, p ∈ ∂h(x̄)
            diff = _tree_flat(svrg.tree_sub(xbar_new, y))
            yq = _tree_flat(svrg.tree_sub(y, qbar_actual))
            # subgradient of h at x̄ (for l1: sign; valid subgradient at 0 is 0)
            lam = _l1_lambda(prox)
            p_vec = lam * jnp.sign(_tree_flat(xbar_new))
            eps = float(jnp.vdot(diff, diff) / (2 * hp.alpha)
                        + jnp.vdot(diff, yq / hp.alpha + p_vec))
            d_eps.append(eps)
            # inexactness inequality (9):
            # 1/(2α)||x̄-q̄||² + h(x̄) ≤ min_y {...} + ε
            def _proxobj(pt):
                dd = _tree_flat(svrg.tree_sub(pt, qbar_actual))
                return float(jnp.vdot(dd, dd) / (2 * hp.alpha) + prox.value(pt))
            lhs = _proxobj(xbar_new)
            rhs = _proxobj(y) + eps
            d_slack.append(rhs - lhs)

            d_cons.append(graphs.consensus_distance(np.stack(
                [np.asarray(_tree_flat(gossip.unstack_tree(x_new, i)))
                 for i in range(m)])))

            params = x_new
            inner_sum = svrg.tree_add(inner_sum, params)
        snapshot_point = jax.tree.map(lambda acc: acc / K_s, inner_sum)

    return Theorem1Diagnostics(
        qbar_residual=np.array(d_qbar),
        mix_mean_residual=np.array(d_mix),
        eps=np.array(d_eps),
        ineq9_slack=np.array(d_slack),
        grad_err_norm=np.array(d_enorm),
        consensus=np.array(d_cons))


def _l1_lambda(prox: prox_lib.Prox) -> float:
    """Extract lambda from an l1 prox name 'l1(lam)'; 0 for others."""
    name = prox.name
    if name.startswith("l1(") and name.endswith(")"):
        return float(name[3:-1])
    return 0.0
