"""Quickstart: DPSVRG vs DSPG on l1-regularized logistic regression.

The paper's core experiment in ~40 lines of public API:

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import algorithm, dpsvrg, gossip, graphs, prox, runner
from repro.data import synthetic


def loss_fn(w, batch):
    logits = batch["features"] @ w
    y = batch["labels"]
    return jnp.mean(-y * logits + jnp.log1p(jnp.exp(logits)))  # paper Eq. 26


def main():
    m = 8                                   # nodes (paper testbed size)
    ds = synthetic.make_paper_dataset("adult_like", scale=0.05)
    data = {k: jnp.asarray(v)
            for k, v in synthetic.partition_per_node(ds, m).items()}
    h = prox.l1(0.01)                       # the non-smooth regularizer
    schedule = graphs.b_connected_ring_schedule(m, b=1)   # ring, connected
    x0 = gossip.stack_tree(jnp.zeros(ds.dim), m)
    problem = algorithm.Problem(loss_fn, h, x0, data)

    hp = dpsvrg.DPSVRGHyperParams(alpha=0.2, beta=1.2, n0=4, num_outer=10)
    algo = algorithm.ALGORITHMS["dpsvrg"](problem, hp)
    hist = runner.run(algo, problem, schedule, record_every=0).history
    base_algo = algorithm.ALGORITHMS["dspg"](
        problem, dpsvrg.DSPGHyperParams(alpha0=0.2),
        int(hist.steps[-1]))
    base = runner.run(base_algo, problem, schedule,
                      record_every=10).history

    flat = {k: v.reshape(-1, *v.shape[2:]) for k, v in data.items()}
    _, ref = dpsvrg.centralized_prox_gd(loss_fn, h, jnp.zeros(ds.dim), flat,
                                        0.4, 3000)
    f_star = float(np.min(ref))
    print(f"F*                ~= {f_star:.5f}")
    print(f"DPSVRG   gap      =  {hist.objective[-1] - f_star:.5f} "
          f"(consensus {hist.consensus[-1]:.1e})")
    print(f"DSPG     gap      =  {base.objective[-1] - f_star:.5f} "
          f"(consensus {base.consensus[-1]:.1e})")
    print(f"same steps ({int(hist.steps[-1])}), constant step for DPSVRG, "
          f"decaying for DSPG — variance reduction wins.")


if __name__ == "__main__":
    main()
