"""Robustness frontier: which algorithm x compression survives a hostile
network cheapest?

Expands a {topology x failure x compression x algorithm} grid through
``repro.scenarios.run_matrix``: every (topology, failure, seed) plane runs
as ONE batched device-resident sweep (O(1) host<->device transfers per
program — the transfer ledgers are printed), and the rows land in a
convergence-vs-wire-bytes table with the Pareto frontier starred.

    PYTHONPATH=src python examples/robustness_frontier.py
"""

import jax.numpy as jnp

from repro import scenarios
from repro.core import algorithm, gossip, graphs, prox
from repro.data import synthetic
try:
    from examples.quickstart import loss_fn
except ImportError:  # run as a script from examples/
    from quickstart import loss_fn


def main():
    m = 8
    ds = synthetic.make_paper_dataset("adult_like", scale=0.02)
    data = {k: jnp.asarray(v)
            for k, v in synthetic.partition_per_node(
                ds, m, heterogeneity=0.5).items()}
    x0 = gossip.stack_tree(jnp.zeros(ds.dim), m)
    problem = algorithm.Problem(loss_fn, prox.l1(0.01), x0, data)

    steps = 150
    result = scenarios.run_matrix(
        problem,
        topologies={
            "ring": graphs.static_schedule(graphs.ring_matrix(m), "ring"),
            "one-peer-expo": graphs.MixingSchedule(
                tuple(graphs.exponential_graph_matrices(m)), b=3, eta=0.5,
                name="one-peer-expo"),
        },
        failures={
            "none": [],
            "links40": [scenarios.LinkFailures(0.4)],
            "churn25": [scenarios.NodeChurn(0.25, dwell=10)],
            "stale2": [scenarios.StaleGossip(2)],
            "stragglers": [scenarios.Stragglers(3.0)],
        },
        algorithms={
            "loopless_dpsvrg": lambda p: algorithm.loopless_dpsvrg_algorithm(
                p, 0.2, steps, snapshot_prob=0.1),
            "dvr": lambda p: algorithm.dvr_algorithm(
                p, 0.2, steps, rho=0.7, snapshot_prob=0.1),
            "gt_svrg": lambda p: algorithm.gt_svrg_algorithm(
                p, 0.1, 5, steps // 5),
        },
        compressions=(None, 8),
        seeds=(0,),
        record_every=steps,
        scenario_seed=0,
    )

    print(scenarios.format_table(result.rows))
    print("\nbatched programs (one per algorithm x compression x transport "
          "spec; each runs its topology x failure x seed plane with O(1) "
          "transfers):")
    for g in result.groups:
        print(f"  {g['algorithm']:16s} {g['compression']:5s} "
              f"delay={g['transport']['delay']} "
              f"straggler_p={g['transport']['straggler_p']:.2f}  "
              f"cells={g['cells']}  transfers h2d={g['transfers_h2d']} "
              f"d2h={g['transfers_d2h']}")
    front = scenarios.pareto_frontier(result.rows)
    best = front[-1]
    print(f"\nfrontier: {len(front)} of {len(result.rows)} cells; "
          f"best objective {best.objective:.5f} at {best.wire_bytes}B "
          f"({best.algorithm}/{best.compression} on {best.topology} "
          f"under {best.failure})")


if __name__ == "__main__":
    main()
