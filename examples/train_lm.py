"""End-to-end driver: decentralized DPSVRG training of a ~100M-parameter
decoder LM for a few hundred steps on synthetic token streams.

    PYTHONPATH=src python examples/train_lm.py \
        --steps 300 --nodes 4 --d-model 512 --layers 12

The default config is ~100M params (12L x 512d x 32k vocab).  On this CPU
container expect a few seconds/step; pass --d-model 128 --layers 4
--vocab 2048 for a quick demo.  Execution defaults to the device-resident
chunked path (one staging transfer, one metrics pull per log window) —
pass --host for the per-step reference loop; the two produce identical
histories.  --resume continues bitwise from the newest checkpoint in
--ckpt-dir, and --tracker jsonl:<path> streams metrics as JSON lines.
The same TrainerConfig drives the production mesh path (see
repro/launch/train.py).
"""

import argparse
import time

from repro.core import graphs, prox
from repro.data import loader, synthetic
from repro.models.api import ModelConfig
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4, help="per-node batch")
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--lam", type=float, default=1e-6)
    ap.add_argument("--algorithm", default="dpsvrg",
                    choices=["dpsvrg", "dspg"])
    ap.add_argument("--gossip", default="auto",
                    choices=["auto", "dense", "banded", "ppermute",
                             "compressed"],
                    help="transport backend (transport.GOSSIP_BACKENDS); "
                         "auto picks banded on band-structured schedules")
    path = ap.add_mutually_exclusive_group()
    path.add_argument("--resident", dest="resident", action="store_true",
                      default=True,
                      help="device-resident chunked execution (default)")
    path.add_argument("--host", dest="resident", action="store_false",
                      help="per-step host loop (reference semantics)")
    ap.add_argument("--sampling", default="host", choices=["host", "device"],
                    help="draw minibatch windows on host (matches --host "
                         "bitwise) or inside the compiled chunk body")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--keep-last", type=int, default=0,
                    help="prune all but N newest checkpoints (0 = keep all)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest checkpoint in --ckpt-dir")
    ap.add_argument("--tracker", default="",
                    help="extra metrics sink, e.g. jsonl:/tmp/metrics.jsonl")
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"lm-{args.layers}x{args.d_model}", arch_type="dense",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1),
        num_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, vocab_size=args.vocab)
    from repro.models import transformer
    import jax
    n = transformer.param_count(
        jax.eval_shape(lambda k: transformer.init_params(cfg, k),
                       jax.random.PRNGKey(0)))
    print(f"model: {cfg.name}, {n/1e6:.1f}M params, {args.nodes} nodes, "
          f"{'resident' if args.resident else 'host'} path")

    stream = synthetic.make_token_stream(2_000_000, cfg.vocab_size, seed=0)
    ld = loader.LMLoader(stream.tokens, num_nodes=args.nodes,
                         per_node_batch=args.batch, seq_len=args.seq)

    sched = graphs.b_connected_ring_schedule(args.nodes, b=2, seed=0)
    tc = trainer.TrainerConfig(
        num_steps=args.steps, snapshot_every=max(args.steps // 6, 25),
        alpha=args.alpha, consensus_rounds=2, algorithm=args.algorithm,
        gossip=args.gossip, log_every=10, ckpt_dir=args.ckpt_dir or None,
        ckpt_every=args.ckpt_every or (100 if args.ckpt_dir else 0),
        keep_last=args.keep_last or None,
        resident=args.resident, sampling=args.sampling,
        tracker=args.tracker or None)
    t0 = time.time()
    hist = trainer.train_loop(cfg, prox.l1(args.lam), sched, ld, tc,
                              resume=args.resume)
    dt = time.time() - t0
    print(f"\nstep  loss    v_norm      wire_MB   alpha")
    for s, l, v, w, a in zip(hist["step"], hist["loss"], hist["v_norm"],
                             hist["wire_bytes"], hist["alpha"]):
        print(f"{s:5d} {l:7.4f} {v:9.2f} {w / 1e6:10.1f} {a:9.5f}")
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({dt / max(args.steps, 1) * 1e3:.0f} ms/step); "
          f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"transfers {hist['transfers']}")


if __name__ == "__main__":
    main()
