"""Continuous-batching serving demo: requests of different lengths stream
through a fixed pool of cache slots; finished sequences retire and new ones
are admitted mid-flight (per-slot position vectors make this exact).

    PYTHONPATH=src python examples/continuous_batching.py --arch gemma2-9b
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serve.scheduler import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=configs.ARCHITECTURES)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=7)
    args = ap.parse_args()

    cfg = configs.smoke_variant(configs.get_config(args.arch))
    if cfg.frontend != "none":
        raise SystemExit("use a text arch for this demo")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    sched = ContinuousBatcher(cfg, params, max_slots=args.slots, max_len=96)
    total_new = 0
    for uid in range(args.requests):
        plen = int(rng.integers(4, 20))
        n_new = int(rng.integers(3, 10))
        total_new += n_new
        sched.submit(Request(
            uid=uid,
            tokens=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=n_new))
        print(f"submitted uid={uid} prompt_len={plen} max_new={n_new}")

    t0 = time.time()
    outs = sched.run_until_done()
    dt = time.time() - t0
    for uid in sorted(outs):
        print(f"uid={uid}: {outs[uid].tolist()}")
    print(f"\n{args.requests} requests ({total_new} tokens) through "
          f"{args.slots} slots in {dt:.1f}s — slot reuse, no head-of-line "
          f"blocking.")


if __name__ == "__main__":
    main()
