"""Batched serving demo: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-9b --new 16

Uses the smoke-reduced variant of any assigned architecture (the full
configs only lower on the production mesh — see repro/launch/dryrun.py).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import multimodal, transformer
from repro.train import steps as steps_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b",
                    choices=configs.ARCHITECTURES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.smoke_variant(configs.get_config(args.arch))
    bundle = steps_lib.build_serve_steps(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len)), jnp.int32)
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["image_embeds"] = jnp.asarray(multimodal.fake_image_patches(
            args.batch, cfg.d_model, cfg.image_tokens))
    if cfg.frontend == "audio_stub":
        kw["audio_frames"] = jnp.asarray(multimodal.fake_audio_frames(
            args.batch, cfg.d_model, cfg.encoder_seq))

    t0 = time.time()
    logits, cache = bundle.prefill_step(
        params, toks, max_len=args.prompt_len + args.new + 64, **kw)
    t_prefill = time.time() - t0
    decode = jax.jit(bundle.decode_step)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [cur]
    t0 = time.time()
    for _ in range(args.new - 1):
        logits, cache = decode(params, cache, cur)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(cur)
    jax.block_until_ready(cur)
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(o) for o in outs], 1)
    print(f"arch={args.arch} (smoke variant), batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill*1e3:.1f} ms")
    print(f"decode  {args.new} toks: {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.new-1,1)*1e3:.1f} ms/tok, "
          f"{args.batch*(args.new-1)/max(t_decode,1e-9):.0f} tok/s)")
    for i in range(min(args.batch, 2)):
        print(f"  seq{i}: {gen[i].tolist()}")


if __name__ == "__main__":
    main()
