"""Time-varying topology deep-dive: watch consensus + convergence as the
communication graph flaps (the paper's Section V-D scenario, plus the
production story — a pod-to-pod link that degrades mid-training).

    PYTHONPATH=src python examples/timevarying_topology.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import dpsvrg, gossip, graphs, prox
from repro.data import synthetic
try:
    from examples.quickstart import loss_fn
except ImportError:  # run as a script from examples/
    from quickstart import loss_fn


def main():
    m = 8
    ds = synthetic.make_paper_dataset("covertype_like", scale=0.02)
    data = {k: jnp.asarray(v)
            for k, v in synthetic.partition_per_node(ds, m).items()}
    h = prox.l1(0.01)
    x0 = gossip.stack_tree(jnp.zeros(ds.dim), m)

    print("schedule                          spectral-gap(W̄)   gap      consensus")
    for sched in [
        graphs.static_schedule(graphs.fully_connected_matrix(m), "complete"),
        graphs.static_schedule(graphs.ring_matrix(m), "static-ring"),
        graphs.MixingSchedule(tuple(graphs.edge_matching_matrices(m)), b=2,
                              eta=0.5, name="tdma-matchings"),
        graphs.MixingSchedule(tuple(graphs.exponential_graph_matrices(m)),
                              b=3, eta=0.5, name="one-peer-expo"),
        graphs.b_connected_ring_schedule(m, b=7, seed=1),
        graphs.random_b_connected_schedule(m, b=4, p_keep=0.4, seed=2),
    ]:
        hp = dpsvrg.DPSVRGHyperParams(alpha=0.2, beta=1.2, n0=4, num_outer=8)
        _, hist = dpsvrg.dpsvrg_run(loss_fn, h, x0, data, sched, hp,
                                    record_every=0)
        wbar = sched.phi(0, sched.period - 1)
        print(f"{sched.name:30s}    {graphs.spectral_gap(wbar):8.4f}      "
              f"{hist.objective[-1]:.5f}  {hist.consensus[-1]:.2e}")
    print("\nLemma 1 in action: denser/better-mixing schedules reach tighter "
          "consensus at equal steps; all b-connected schedules converge.")


if __name__ == "__main__":
    main()
