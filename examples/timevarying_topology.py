"""Time-varying topology deep-dive: watch consensus + convergence as the
communication graph flaps (the paper's Section V-D scenario, plus the
production story — a pod-to-pod link that degrades mid-training, here a
first-class ``repro.scenarios`` event model instead of a hand-rolled
schedule).

    PYTHONPATH=src python examples/timevarying_topology.py
"""

import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import algorithm, dpsvrg, gossip, graphs, prox, runner
from repro.data import synthetic
from repro.core.exec_spec import ExecSpec
try:
    from examples.quickstart import loss_fn
except ImportError:  # run as a script from examples/
    from quickstart import loss_fn


def main():
    m = 8
    ds = synthetic.make_paper_dataset("covertype_like", scale=0.02)
    data = {k: jnp.asarray(v)
            for k, v in synthetic.partition_per_node(ds, m).items()}
    h = prox.l1(0.01)
    x0 = gossip.stack_tree(jnp.zeros(ds.dim), m)
    problem = algorithm.Problem(loss_fn, h, x0, data)
    matchings = graphs.edge_matching_matrices(m)
    tdma = graphs.MixingSchedule(tuple(matchings), b=len(matchings), eta=0.5,
                                 name="tdma-matchings")
    ring = graphs.static_schedule(graphs.ring_matrix(m), "static-ring")

    # benign schedules plus the SAME ring degraded by seeded network events:
    # scenarios.apply composes link-failure / churn models over any base
    # schedule, Metropolis-reweighting every realized W^t so Assumption 2
    # (double stochasticity) survives the degradation
    cases = [
        (graphs.static_schedule(graphs.fully_connected_matrix(m),
                                "complete"), []),
        (ring, []),
        (tdma, []),
        (graphs.MixingSchedule(tuple(graphs.exponential_graph_matrices(m)),
                               b=3, eta=0.5, name="one-peer-expo"), []),
        (graphs.b_connected_ring_schedule(m, b=7, seed=1), []),
        (graphs.random_b_connected_schedule(
            m, b=4, p_keep=0.4, seed=np.random.default_rng(2)), []),
        (ring, [scenarios.LinkFailures(0.3)]),
        (ring, [scenarios.NodeChurn(0.2, dwell=10)]),
        (ring, [scenarios.LinkFailures(0.3), scenarios.NodeChurn(0.1)]),
    ]

    print("schedule                                spectral-gap(W̄)   gap      consensus")
    for base, models in cases:
        sched, backend = scenarios.apply(base, models, seed=7)
        hp = dpsvrg.DPSVRGHyperParams(alpha=0.2, beta=1.2, n0=4, num_outer=8)
        algo = algorithm.ALGORITHMS["dpsvrg"](problem, hp)
        hist = runner.run(algo, problem, sched, exec=ExecSpec(gossip=backend if models else "auto"), record_every=0).history
        # the UNDEGRADED period-average gap; degraded realizations mix slower
        wbar = base.phi(0, base.period - 1)
        print(f"{sched.name:36s}    {graphs.spectral_gap(wbar):8.4f}      "
              f"{hist.objective[-1]:.5f}  {hist.consensus[-1]:.2e}")
    print("\nLemma 1 in action: denser/better-mixing schedules reach tighter "
          "consensus at equal steps; seeded link failures and node churn "
          "slow consensus but b-connected-in-expectation schedules still "
          "converge.")

    # transport-level degradation: payloads arrive 2 slots stale and half
    # the nodes are 2x-slowed stragglers — the delay buffer threads through
    # the algorithm's mix state, so the run stays scan/resident-compatible
    sched, backend = scenarios.apply(
        ring, [scenarios.StaleGossip(2), scenarios.Stragglers(2.0)], seed=7)
    algo = algorithm.ALGORITHMS["loopless_dpsvrg"](
        problem, 0.2, 200, snapshot_prob=0.05)
    res = runner.run(algo, problem, sched, exec=ExecSpec(resident=True, gossip=backend), record_every=50)
    hist = res.history
    print(f"stale+straggler gossip (resident): F={hist.objective[-1]:.5f} "
          f"consensus={hist.consensus[-1]:.2e} "
          f"wire={np.asarray(res.extras['wire_bytes'])[-1] / 1e3:.0f}kB")

    # the TDMA matchings have degree <= 2: the same run gossips in O(degree)
    # banded collectives (scan fast path) with a float-tolerance-equal
    # history — gossip="auto" detects the band structure and selects the
    # banded transport; the wire_bytes extras column reports the bytes moved
    hp = dpsvrg.DPSVRGHyperParams(alpha=0.2, beta=1.2, n0=4, num_outer=8,
                                  k_max=2)
    algo = algorithm.ALGORITHMS["dpsvrg"](problem, hp)
    res = runner.run(algo, problem, tdma, exec=ExecSpec(scan=True, gossip="auto"), record_every=0)
    hist = res.history
    print(f"banded-gossip scan on tdma-matchings: F={hist.objective[-1]:.5f} "
          f"consensus={hist.consensus[-1]:.2e} "
          f"wire={res.extras['wire_bytes'][-1] / 1e3:.0f}kB")


if __name__ == "__main__":
    main()
