"""The paper's full experimental setting: time-varying graphs, multi- vs
single-consensus, lambda sweep — a runnable mini version of Figs. 1-5.

    PYTHONPATH=src python examples/decentralized_logreg.py [--scale 0.02]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import algorithm, dpsvrg, gossip, graphs, prox, runner
from repro.data import synthetic
try:
    from examples.quickstart import loss_fn
except ImportError:  # run as a script from examples/
    from quickstart import loss_fn


def run_setting(dataset, m, b, lam, alpha, num_outer, scale, single=False):
    ds = synthetic.make_paper_dataset(dataset, scale=scale)
    data = {k: jnp.asarray(v)
            for k, v in synthetic.partition_per_node(ds, m).items()}
    h = prox.l1(lam)
    sched = graphs.b_connected_ring_schedule(m, b=b, seed=b)
    x0 = gossip.stack_tree(jnp.zeros(ds.dim), m)
    problem = algorithm.Problem(loss_fn, h, x0, data)
    hp = dpsvrg.DPSVRGHyperParams(alpha=alpha, beta=1.2, n0=4,
                                  num_outer=num_outer,
                                  single_consensus=single)
    hv = runner.run(algorithm.ALGORITHMS["dpsvrg"](problem, hp), problem,
                    sched, record_every=0).history
    hd = runner.run(
        algorithm.ALGORITHMS["dspg"](
            problem, dpsvrg.DSPGHyperParams(alpha0=alpha),
            int(hv.steps[-1])),
        problem, sched, seed=b, record_every=10).history
    return hv, hd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    args = ap.parse_args()

    print("== graph connectivity sweep (Fig. 5): b in {1, 3, 7} ==")
    for b in (1, 3, 7):
        hv, hd = run_setting("mnist_like", 8, b, 0.01, 0.2, 9, args.scale)
        print(f"  b={b}: DPSVRG F={hv.objective[-1]:.5f} "
              f"(consensus {hv.consensus[-1]:.1e})  "
              f"DSPG F={hd.objective[-1]:.5f}")

    print("== lambda sweep (Fig. 4) ==")
    for lam in (0.001, 0.01, 0.1):
        hv, hd = run_setting("mnist_like", 8, 1, lam, 0.2, 9, args.scale)
        osc = float(np.std(hd.objective[-4:]))
        print(f"  lam={lam}: DPSVRG F={hv.objective[-1]:.5f}  "
              f"DSPG F={hd.objective[-1]:.5f} (osc {osc:.1e})")

    print("== multi vs single consensus (Fig. 3) ==")
    for single in (False, True):
        hv, _ = run_setting("mnist_like", 8, 3, 0.01, 0.2, 9, args.scale,
                            single=single)
        print(f"  {'single' if single else 'multi '}: "
              f"F={hv.objective[-1]:.5f} consensus={hv.consensus[-1]:.1e} "
              f"comm={int(hv.comm_rounds[-1])}")


if __name__ == "__main__":
    main()
